"""Sharded checkpointing with resharding restore and async saves.

Layout: <dir>/step_<N>/
  manifest.json   -- tree structure, shapes, dtypes, step
  arrays.npz      -- flattened leaves keyed by tree path

Design points for 1000+ nodes (DESIGN.md §10):
  * save() snapshots device arrays to host then writes on a background
    thread -- the train loop never blocks on the filesystem;
  * restore(..., shardings=...) device_puts each leaf with the TARGET
    sharding, so a checkpoint written on one mesh restores onto another
    (elastic scaling / failover to a different slice topology);
  * latest_step() + atomic rename give crash-consistent resume;
  * in a true multi-host deployment each host would write its local
    shards (jax.experimental.multihost_utils); single-process here, the
    layout and restore-with-resharding semantics are what we validate.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

_EXECUTOR = ThreadPoolExecutor(max_workers=1)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, state, *, async_: bool = True) -> Future:
    """Snapshot `state` and write step_<N> atomically. Returns a Future."""
    flat, _ = _flatten_with_paths(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # bf16 is not a numpy-native dtype: store via uint16 view + dtype tag
    meta = {}
    arrays = {}
    for k, v in host.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            meta[k] = "bfloat16"
        else:
            arrays[k] = v
            meta[k] = str(v.dtype)

    def write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "dtypes": meta,
                       "keys": sorted(arrays.keys())}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    if async_:
        return _EXECUTOR.submit(write)
    fut: Future = Future()
    fut.set_result(write())
    return fut


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, state_like, *, shardings=None):
    """Load step_<N> into the structure of `state_like`.

    shardings: optional pytree of jax.sharding.Sharding (same structure) --
    each leaf is device_put with its target sharding, implementing
    restore-onto-a-different-mesh (elastic scaling)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = _flatten_with_paths(state_like)
    shard_flat = None
    if shardings is not None:
        shard_flat, _ = _flatten_with_paths(shardings)

    restored = {}
    for key, like in flat_like.items():
        arr = data[key]
        if manifest["dtypes"][key] == "bfloat16":
            arr = arr.view(np.dtype("uint16"))
            arr = jax.numpy.asarray(arr).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(arr)
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[key])
        restored[key] = arr

    # flat_like preserves canonical tree_flatten order -> safe to unflatten
    leaves = [restored[k] for k in flat_like.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves)
