import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the
# device count on first init).  This module -- and ONLY this module --
# sees 512 placeholder CPU devices so the 16x16 and 2x16x16 production
# meshes can be built; smoke tests and benchmarks see 1 device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, prove it fits, and extract roofline terms.

Per cell:
  jit(step).lower(ShapeDtypeStructs-with-shardings).compile()
  -> compiled.memory_analysis()   (proves the memory plan fits 16 GB/chip)
  -> compiled.cost_analysis()     (FLOPs / bytes for EXPERIMENTS.md §Roofline)
  -> HLO text collective parse    (collective roofline term)

Usage:
  python -m repro.launch.dryrun --cell qwen3-4b:train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
  python -m repro.launch.dryrun --geostat geostat_500k --mesh single
(--all spawns one subprocess per cell for isolation.)
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ALL_ARCHS, GEOSTAT_CONFIGS, SHAPES, cell_applicable,
                       input_specs)
from ..models.sharding import resolve_spec, tree_resolve_shardings
from ..train import TrainConfig, make_train_step
from .mesh import make_production_mesh, mesh_num_devices
from .roofline import analyze_compiled, lm_model_flops

HBM_PER_CHIP = 16 * 2 ** 30  # v5e


# ------------------------------------------------------------ shardings

def _greedy_cache_sharding(mesh, leaf, *, batch_dim=1):
    """Auto-shard a cache/state leaf: batch over (pod, data) when it
    divides; then the largest remaining dims over unused axes."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = [None] * leaf.ndim
    used = set()
    if leaf.ndim > batch_dim:
        b = leaf.shape[batch_dim]
        axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
        if axes and all(b % axis_sizes[a] == 0 for a in axes) and \
                b % int(np.prod([axis_sizes[a] for a in axes])) == 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
            used.update(axes)
    # remaining dims, largest first (skip dim 0 = stacked cycles)
    order = sorted(range(1, leaf.ndim), key=lambda i: -leaf.shape[i])
    for ax_name in mesh.axis_names:
        if ax_name in used:
            continue
        for i in order:
            if spec[i] is None and leaf.shape[i] % axis_sizes[ax_name] == 0 \
                    and leaf.shape[i] >= axis_sizes[ax_name] * 8:
                spec[i] = ax_name
                used.add(ax_name)
                break
    return NamedSharding(mesh, P(*spec))


def _with_sharding(struct_tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, shardings)


def _batch_shardings(mesh, batch_tree):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(leaf):
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = int(np.prod([axis_sizes[a] for a in axes]))
        if leaf.shape[0] % total == 0:
            spec = (axes if len(axes) > 1 else axes[0],) + (None,) * (leaf.ndim - 1)
        else:
            spec = (None,) * leaf.ndim
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_tree)


def _param_shardings(mesh, cfg, rules=None):
    from ..models.transformer import init_lm
    box = {}

    def params_only(key):
        p, axes = init_lm(key, cfg)
        box["axes"] = axes  # strings: side-channel out of the trace
        return p

    shapes = jax.eval_shape(params_only, jax.random.PRNGKey(0))
    shardings = jax.tree.map(
        lambda s, a: NamedSharding(mesh, resolve_spec(a, mesh, rules,
                                                      shape=s.shape)),
        shapes, box["axes"])
    return shapes, shardings


def _rules_for_opts(opts):
    from ..models.sharding import DEFAULT_RULES
    rules = dict(DEFAULT_RULES)
    if opts.get("no_fsdp"):
        rules["embed"] = ()   # replicate params over data (pure DP)
    return rules


# ------------------------------------------------------------ LM cells

# Per-arch production knobs for the train cells, sized so fp32 master +
# Adam + remat'd activations fit 16 GB/chip (EXPERIMENTS.md §Dry-run).
# remat_group: 2-level remat group size; microbatches: grad accumulation;
# moment_dtype: bf16 first moment (grok-1's 314B x 12B/param squeeze).
TRAIN_OVERRIDES = {
    "grok-1-314b": dict(microbatches=8, moment_dtype="bfloat16",
                        remat_group=8),
    "qwen3-32b": dict(microbatches=2, remat_group=8),
    "llava-next-34b": dict(microbatches=2, remat_group=6),
    "qwen3-moe-30b-a3b": dict(remat_group=8),
    "jamba-v0.1-52b": dict(microbatches=2, remat_group=2),
    "xlstm-1.3b": dict(remat_group=8),
    "h2o-danube-1.8b": dict(remat_group=4),
    "qwen3-4b": dict(remat_group=6),
    "llama3.2-1b": dict(remat_group=4),
}


def arch_for_cell(arch: str):
    import dataclasses as _dc
    cfg = ALL_ARCHS[arch]
    ov = TRAIN_OVERRIDES.get(arch, {})
    if "remat_group" in ov:
        cfg = _dc.replace(cfg, remat_group=ov["remat_group"])
    return cfg


def lower_lm_cell(arch: str, shape_name: str, mesh, opts=None):
    opts = opts or {}
    cfg = arch_for_cell(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    if opts.get("kv_quant") and shape.kind == "decode":
        from ..models.decode import init_cache
        specs["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               kv_quant=True))
    rules = _rules_for_opts(opts)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        ov = TRAIN_OVERRIDES.get(arch, {})
        tc = TrainConfig(microbatches=ov.get("microbatches", 1),
                         moment_dtype=("bfloat16" if opts.get("moment_bf16")
                                       else ov.get("moment_dtype", "float32")),
                         compression=opts.get("compression", "none"))
        p_shapes, p_shard = _param_shardings(mesh, cfg, rules)
        mdt = jnp.bfloat16 if tc.moment_dtype == "bfloat16" else jnp.float32
        m_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_shapes)
        state_shapes = {
            "params": p_shapes,
            "opt": {"m": m_shapes, "v": p_shapes,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)},
            "data_step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_shard = {
            "params": p_shard,
            "opt": {"m": p_shard, "v": p_shard, "step": repl},
            "data_step": repl,
        }
        if tc.compression != "none":
            state_shapes["residual"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_shapes)
            state_shard["residual"] = p_shard
        state_in = _with_sharding(state_shapes, state_shard)
        batch_in = _with_sharding(specs, _batch_shardings(mesh, specs))
        step = make_train_step(cfg, tc)
        lowered = jax.jit(step, donate_argnums=(0,)).lower(state_in, batch_in)
        return lowered, lm_model_flops(cfg, shape)

    p_shapes, p_shard = _param_shardings(mesh, cfg, rules)
    params_in = _with_sharding(p_shapes, p_shard)

    if shape.kind == "prefill":
        from ..models.decode import prefill

        def prefill_fn(params, batch):
            return prefill(params, batch["tokens"], cfg,
                           extra_embeds=batch.get("patches"),
                           frames=batch.get("frames"))

        batch_in = _with_sharding(specs, _batch_shardings(mesh, specs))
        lowered = jax.jit(prefill_fn).lower(params_in, batch_in)
        return lowered, lm_model_flops(cfg, shape)

    # decode
    from ..models.decode import decode_step
    cache_shard = jax.tree.map(lambda s: _greedy_cache_sharding(mesh, s),
                               specs["cache"])
    cache_in = _with_sharding(specs["cache"], cache_shard)
    tokens_in = _with_sharding(
        {"t": specs["tokens"]}, _batch_shardings(mesh, {"t": specs["tokens"]}))["t"]
    pos_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)

    def decode_fn(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg)

    lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(
        params_in, cache_in, tokens_in, pos_in)
    return lowered, lm_model_flops(cfg, shape)


# -------------------------------------------------------- geostat cells

def lower_geostat_cell(name: str, mesh, version: str = "masked_full"):
    from ..core import PrecisionPolicy
    from ..core.distributed import geostat_loglik_distributed
    gc = GEOSTAT_CONFIGS[name]
    policy = PrecisionPolicy.tpu(diag_thick=gc.diag_thick)
    n, nb = gc.n, gc.nb

    locs = jax.ShapeDtypeStruct((n, 2), jnp.float32,
                                sharding=NamedSharding(mesh, P()))
    z = jax.ShapeDtypeStruct((n,), jnp.float32,
                             sharding=NamedSharding(mesh, P()))
    theta = jax.ShapeDtypeStruct((3,), jnp.float32,
                                 sharding=NamedSharding(mesh, P()))

    def step(locs, z, theta):
        return geostat_loglik_distributed(locs, z, theta, nb=nb,
                                          policy=policy, nu_static=gc.nu,
                                          version=version)

    lowered = jax.jit(step).lower(locs, z, theta)
    model_flops = n ** 3 / 3.0  # useful Cholesky FLOPs
    return lowered, model_flops


# -------------------------------------------------------------- driver

def run_cell(kind: str, arch: str, shape_name: str, mesh_mode: str,
             out_dir: str, opts=None):
    opts = opts or {}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_mode == "multi"))
    from ..models.sharding import set_activation_mesh
    set_activation_mesh(mesh)
    chips = mesh_num_devices(mesh)
    suffix = ("+" + "+".join(sorted(k for k, v in opts.items() if v))
              if any(opts.values()) else "")
    name = f"{arch}:{shape_name}:{mesh_mode}{suffix}"
    if kind == "lm":
        lowered, model_flops = lower_lm_cell(arch, shape_name, mesh, opts)
    else:
        lowered, model_flops = lower_geostat_cell(
            arch, mesh, version=opts.get("geo_version", "masked_full"))
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)
    mem_d = {k: int(getattr(mem, k)) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes")}
    peak = (mem_d["argument_size_in_bytes"] + mem_d["output_size_in_bytes"]
            + mem_d["temp_size_in_bytes"] - mem_d["alias_size_in_bytes"])
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})

    # raw compiled-module numbers (loop bodies counted once -- see
    # costmodel.py docstring) are kept as a transparency cross-check
    raw = analyze_compiled(name, mesh_mode, chips, compiled,
                           model_flops=model_flops)

    # primary roofline terms: analytic cost model
    from .costmodel import geostat_cell_cost, lm_cell_cost
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if kind == "lm":
        cfg = arch_for_cell(arch)
        shape = SHAPES[shape_name]
        mb = TRAIN_OVERRIDES.get(arch, {}).get("microbatches", 1) \
            if shape.kind == "train" else 1
        cc = lm_cell_cost(cfg, shape, chips=chips, mesh_axes=mesh_axes,
                          microbatches=mb, opts=opts)
    else:
        gc = GEOSTAT_CONFIGS[arch]
        cc = geostat_cell_cost(
            gc.n, gc.nb, gc.diag_thick, chips=chips,
            off_update=opts.get("geo_version", "masked_full"))

    from .roofline import RooflineReport
    rep = RooflineReport(
        name=name, mesh=mesh_mode, chips=chips,
        flops_per_chip=cc.flops / chips,
        bytes_per_chip=cc.hbm_bytes / chips,
        collective_bytes_per_chip=cc.collective_bytes_per_chip,
        model_flops=cc.model_flops,
        extras={"memory": mem_d,
                "peak_bytes_per_chip": peak,
                "fits_hbm": bool(peak <= HBM_PER_CHIP),
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "cost_detail": {k: float(v) for k, v in cc.detail.items()
                                if isinstance(v, (int, float))},
                "raw_compiled": {
                    "flops_per_chip": raw.flops_per_chip,
                    "bytes_per_chip": raw.bytes_per_chip,
                    "collective_bytes_per_chip":
                        raw.collective_bytes_per_chip,
                    "collectives": raw.extras["collectives"],
                    "note": "while bodies counted once; bf16 buffers "
                            "f32-inflated by the CPU backend"}},
    ).finalize()
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_mode}{suffix}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rep.to_dict(), f, indent=1)
    print(f"[dryrun] {name}: chips={chips} "
          f"flops/chip={rep.flops_per_chip:.3e} "
          f"t_comp={rep.t_compute*1e3:.2f}ms t_mem={rep.t_memory*1e3:.2f}ms "
          f"t_coll={rep.t_collective*1e3:.2f}ms bottleneck={rep.bottleneck} "
          f"peak={peak/2**30:.2f}GiB fits={peak <= HBM_PER_CHIP} "
          f"compile={t_compile:.0f}s")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape")
    ap.add_argument("--geostat", help="geostat config name")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=float, default=2400.0)
    ap.add_argument("--opts", default="",
                    help="comma list: no_fsdp,kv_quant,moment_bf16,"
                         "compression=bf16,geo_version=aligned")
    args = ap.parse_args()

    opts = {}
    for item in filter(None, args.opts.split(",")):
        if "=" in item:
            k, v = item.split("=", 1)
            opts[k] = v
        else:
            opts[item] = True

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = []
        for arch, cfg in ALL_ARCHS.items():
            for sname, shape in SHAPES.items():
                ok, why = cell_applicable(cfg, shape)
                if ok:
                    cells.append(("lm", arch, sname))
                else:
                    print(f"[dryrun] SKIP {arch}:{sname}: {why}")
        for g in ("geostat_500k", "geostat_1m"):
            cells.append(("geo", g, "-"))
        failures = []
        for kind, arch, sname in cells:
            for m in meshes:
                if kind == "geo" and ((arch == "geostat_1m") != (m == "multi")):
                    continue  # 1m is the multi-pod geostat cell
                fname = f"{arch}__{sname}__{m}.json".replace("/", "_")
                if os.path.exists(os.path.join(args.out, fname)):
                    print(f"[dryrun] cached {arch}:{sname}:{m}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--mesh", m, "--out", args.out]
                cmd += (["--geostat", arch] if kind == "geo"
                        else ["--cell", f"{arch}:{sname}"])
                print(f"[dryrun] >>> {arch}:{sname}:{m}")
                try:
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append((arch, sname, m, r.returncode))
                except subprocess.TimeoutExpired:
                    failures.append((arch, sname, m, "timeout"))
        print(f"[dryrun] done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    if args.geostat:
        run_cell("geo", args.geostat, "-", meshes[0], args.out, opts)
        return
    arch, sname = args.cell.split(":")
    run_cell("lm", arch, sname, meshes[0], args.out, opts)


if __name__ == "__main__":
    main()
