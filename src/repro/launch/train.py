"""End-to-end training launcher.

Single-process usage (CPU container / one host of a pod):

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --preset smoke --steps 100 --ckpt-dir /tmp/run1

On a real multi-host TPU pod each host runs this same entrypoint after
jax.distributed.initialize(); the data pipeline shards by process_index,
params/optimizer shard per models/sharding.py rules, and the
fault-tolerant loop resumes from the latest checkpoint after any restart
(the controller just relaunches the job -- see DESIGN.md §10).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from ..configs import ALL_ARCHS, SMOKE_ARCHS
from ..data import DataConfig, SyntheticTokenSource
from ..runtime import FaultTolerantLoop, LoopConfig
from ..train import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ALL_ARCHS))
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke",
                    help="smoke = reduced config for CPU; full = assigned "
                         "config (TPU pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", choices=["none", "bf16", "int8"],
                    default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.preset == "smoke" else ALL_ARCHS)[args.arch]
    tc = TrainConfig(peak_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                     total_steps=args.steps, microbatches=args.microbatches,
                     compression=args.compression)
    state, axes = init_train_state(jax.random.PRNGKey(args.seed), cfg, tc)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={n_params/1e6:.1f}M devices={jax.device_count()}")

    step_fn = jax.jit(make_train_step(cfg, tc))
    src = SyntheticTokenSource(cfg, DataConfig(
        seed=args.seed, global_batch=args.global_batch, seq_len=args.seq_len,
        n_processes=jax.process_count(), process_index=jax.process_index()))

    lc = LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    max_steps=args.steps)
    loop = FaultTolerantLoop(lc, step_fn, src, state)
    state = loop.run()
    losses = [m["loss"] for m in loop.metrics_log]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"[train] loss first-{k}-avg={sum(losses[:k])/k:.4f} "
              f"last-{k}-avg={sum(losses[-k:])/k:.4f} steps={len(losses)}")
    with open(os.path.join(args.ckpt_dir, "metrics.json"), "w") as f:
        json.dump(loop.metrics_log, f)
    return state


if __name__ == "__main__":
    main()
