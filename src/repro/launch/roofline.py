"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

  compute    = HLO_FLOPs / (chips x 197e12)          [bf16 peak, v5e]
  memory     = HLO_bytes / (chips x 819e9)           [HBM]
  collective = collective_bytes / (chips x 50e9)     [ICI link]

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis().  XLA reports
these for the *partitioned per-device module*, so chips-normalization is
already done -- we multiply back up to globals for reporting and divide
per the formulas (validated in tests/test_roofline.py on a known matmul).

collective_bytes is not in cost_analysis: we parse the optimized HLO text
and sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.
"""

from __future__ import annotations

import dataclasses
import json
import re

from .mesh import HBM_BW, ICI_LINK_BW, PEAK_BF16_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4,
    "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2,
    "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the optimized HLO.

    Returns {op_kind: bytes, ..., "total": bytes, "count": n}.
    `hlo_text` is the per-device partitioned module, so these are
    per-device bytes entering the network fabric.
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        kind = None
        for k in _COLLECTIVES:
            # op name directly after result type, e.g.
            # %ar = f32[1024]{0} all-reduce(...)
            if re.search(rf"\}}?\s{re.escape(k)}(-start|-done)?\(", rhs) or \
               re.match(rf"^\(?[a-z0-9]+\[.*\s{re.escape(k)}(-start|-done)?\(",
                        rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done" in rhs:
            continue  # counted at -start
        # result types: everything before the op name token
        head = rhs.split(kind)[0]
        nbytes = sum(_type_bytes(d, dims) for d, dims in _TYPE_RE.findall(head))
        out[kind] += nbytes
        count += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float          # 6*N*D useful-FLOPs reference (0 if n/a)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    extras: dict = dataclasses.field(default_factory=dict)

    def finalize(self):
        self.t_compute = self.flops_per_chip / PEAK_BF16_FLOPS
        self.t_memory = self.bytes_per_chip / HBM_BW
        self.t_collective = self.collective_bytes_per_chip / ICI_LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        return self

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (catches remat/redundancy waste)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        compute: t_useful_compute / max(all terms)."""
        t_useful = (self.model_flops / self.chips) / PEAK_BF16_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["useful_flops_fraction"] = self.useful_flops_fraction
        d["roofline_fraction"] = self.roofline_fraction
        return d


def analyze_compiled(name: str, mesh_name: str, chips: int, compiled,
                     model_flops: float = 0.0, extras: dict | None = None
                     ) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(compiled.as_text())
    rep = RooflineReport(
        name=name, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=nbytes,
        collective_bytes_per_chip=float(coll["total"]),
        model_flops=model_flops,
        extras={"collectives": coll, **(extras or {})},
    )
    return rep.finalize()


def lm_model_flops(cfg, shape) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE); decode: D = global_batch tokens."""
    n_params = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params * tokens
    return 2.0 * n_params * shape.global_batch  # decode: one token per seq


def active_param_count(cfg) -> int:
    """Per-token active parameters (MoE counts top_k experts only)."""
    import jax
    from ..models.transformer import init_lm

    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg)[0])
    total = sum(int(__import__("numpy").prod(s.shape))
                for s in jax.tree.leaves(shapes))
    if cfg.moe is not None:
        # subtract the inactive expert fraction
        per_expert = 3 * cfg.d_model * cfg.moe.d_expert
        n_moe_layers = sum(1 for i in range(len(cfg.block_pattern))
                           if cfg.layer_is_moe(i)) * cfg.n_cycles
        inactive = (cfg.moe.n_experts - cfg.moe.top_k) * per_expert * n_moe_layers
        total -= inactive
    return total


def save_report(path: str, rep: RooflineReport):
    with open(path, "w") as f:
        json.dump(rep.to_dict(), f, indent=1)
