"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (critical: tests must see 1 CPU device; only dryrun.py
forces 512 placeholder devices via XLA_FLAGS before any jax import).

Topology: TPU v5e pods, 16x16 = 256 chips per pod.
  single pod : (16, 16)    axes ("data", "model")
  multi pod  : (2, 16, 16) axes ("pod", "data", "model") -- "pod" is the
               DCN-connected second data-parallel tier.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1x1 mesh with production axis names: same model/sharding code paths
    on a single CPU device."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_num_devices(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_BF16_FLOPS = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link
