"""Analytic per-cell cost model for the roofline terms.

WHY ANALYTIC: XLA's compiled-module cost_analysis counts while-loop bodies
ONCE, not x trip-count.  Our production graphs are scan-heavy (cycles,
microbatches, query chunks, SSM steps), so raw cost_analysis undercounts
FLOPs/bytes by 10-1000x depending on arch.  The roofline table therefore
uses this analytic model -- exact for matmul FLOPs, explicit-assumption
traffic models for HBM bytes and collective bytes -- and the test suite
validates the FLOPs model against cost_analysis on small UNROLLED variants
(tests/test_costmodel.py).  Raw cost_analysis numbers are still recorded
in the dry-run JSONs (extras) for transparency.

All quantities are GLOBAL per optimizer step (train) or per token step
(decode/prefill); the roofline report divides by chips.

Key modelling assumptions (documented per EXPERIMENTS.md §Methodology):
  * backward = 2x forward matmul FLOPs; full remat adds ~1x recompute
  * bf16 activations (2 B), fp32 params/moments (4 B), bf16 KV cache
  * FSDP all-gather: ~P bytes per chip per traversal of the params;
    grad reduce-scatter+all-gather ~ 2P bytes; ring all-reduce ~ 2X bytes
  * TP all-reduce: 2 x activation bytes per (attn, mlp) block output
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from ..models.config import ArchConfig
from ..configs.shapes import ShapeSpec

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CellCost:
    flops: float                # global FLOPs per step (bf16-equivalent)
    hbm_bytes: float            # global HBM traffic per step
    collective_bytes_per_chip: float
    model_flops: float          # 6*N_active*D (train) / 2*N_active*D (serve)
    detail: dict


# ---------------------------------------------------------------- blocks

def _attn_flops(cfg: ArchConfig, b, s, skv=None, causal=True):
    skv = skv or s
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    if cfg.swa_window and causal:
        skv_eff = min(cfg.swa_window, skv)
    else:
        skv_eff = skv
    proj = 2 * b * s * d * (h + 2 * kv) * hd + 2 * b * s * h * hd * d
    factor = 0.5 if (causal and skv == s and not cfg.swa_window) else 1.0
    if cfg.swa_window and causal:
        factor = 1.0  # window already truncates skv_eff
    scores = 2 * 2 * b * s * skv_eff * h * hd * factor
    return proj + scores


def _mlp_flops(cfg, b, s):
    return 3 * 2 * b * s * cfg.d_model * cfg.d_ff


def _moe_flops(cfg, b, s):
    spec = cfg.moe
    t_eff = spec.capacity_factor * spec.top_k * b * s
    router = 2 * b * s * cfg.d_model * spec.n_experts
    experts = 3 * 2 * t_eff * cfg.d_model * spec.d_expert
    return router + experts


def _mamba_flops(cfg, b, s):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    r = max(1, d // 16)
    return (2 * b * s * d * 2 * d_in          # in_proj
            + 2 * b * s * d_in * cfg.ssm_conv  # conv
            + 2 * b * s * d_in * (r + 2 * n)   # x_proj
            + 2 * b * s * r * d_in             # dt_proj
            + 8 * b * s * d_in * n             # scan + y einsum
            + 2 * b * s * d_in * d)            # out_proj


def _mlstm_flops(cfg, b, s):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    hd = d_in // cfg.n_heads
    return (2 * b * s * d * 2 * d_in
            + 3 * 2 * b * s * d_in * d_in
            + 6 * b * s * d_in * hd            # C update + readout
            + 2 * b * s * d_in * d)


def _slstm_flops(cfg, b, s):
    d = cfg.d_model
    hd = d // cfg.n_heads
    return (2 * b * s * d * 4 * d
            + 8 * b * s * d * hd               # block-diag recurrence
            + 2 * b * s * d * d)


_BLOCK_FLOPS = {"attn": _attn_flops, "mamba": _mamba_flops,
                "mlstm": _mlstm_flops, "slstm": _slstm_flops}


def _forward_flops(cfg: ArchConfig, b, s, *, causal=True):
    total = 0.0
    for i in range(cfg.n_layers):
        bt = cfg.layer_block_type(i)
        if bt == "attn":
            total += _attn_flops(cfg, b, s, causal=causal)
        else:
            total += _BLOCK_FLOPS[bt](cfg, b, s)
        if cfg.layer_is_moe(i):
            total += _moe_flops(cfg, b, s)
        elif cfg.d_ff and bt in ("attn", "mamba"):
            total += _mlp_flops(cfg, b, s)
    if cfg.enc_dec:
        f = cfg.n_enc_frames
        for _ in range(cfg.n_enc_layers):
            total += _attn_flops(cfg, b, f, causal=False) + _mlp_flops(cfg, b, f)
        total += cfg.n_layers * (  # decoder cross attention
            2 * b * s * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head
            + 2 * 2 * b * s * f * cfg.n_heads * cfg.d_head
            + 2 * b * s * cfg.n_heads * cfg.d_head * cfg.d_model)
    total += 2 * b * s * cfg.d_model * cfg.vocab   # logits
    return total


def _param_bytes(cfg: ArchConfig, dtype_bytes=F32):
    from .roofline import active_param_count
    import jax
    from ..models.transformer import init_lm
    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg)[0])
    import numpy as np
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    return total * dtype_bytes, total


def _act_bytes_per_layer(cfg, b, s):
    return b * s * cfg.d_model * BF16


def _cache_bytes(cfg: ArchConfig, b, s, *, kv_quant: bool = False):
    kv, hd = cfg.n_kv_heads, cfg.d_head
    attn_bytes = (1 + 4.0 / hd) if kv_quant else BF16  # int8 + fp32 scale
    total = 0
    for i in range(cfg.n_layers):
        bt = cfg.layer_block_type(i)
        if bt == "attn":
            w = min(cfg.swa_window or s, s)
            total += 2 * b * w * kv * hd * attn_bytes
        elif bt == "mamba":
            d_in = cfg.ssm_expand * cfg.d_model
            total += b * d_in * (cfg.ssm_d_state * F32 + (cfg.ssm_conv - 1) * BF16)
        elif bt == "mlstm":
            d_in = cfg.ssm_expand * cfg.d_model
            hd_i = d_in // cfg.n_heads
            total += b * cfg.n_heads * (hd_i * hd_i + hd_i + 1) * F32
        elif bt == "slstm":
            total += 4 * b * cfg.d_model * F32
    if cfg.enc_dec:
        total += cfg.n_layers * 2 * b * cfg.n_enc_frames * kv * hd * BF16
    return total


# ------------------------------------------------------------------ cells

def lm_cell_cost(cfg: ArchConfig, shape: ShapeSpec, *, chips: int,
                 mesh_axes: dict, microbatches: int = 1,
                 opts: dict | None = None) -> CellCost:
    """mesh_axes: {"data": 16, "model": 16, ["pod": 2]}.

    opts (perf variants, EXPERIMENTS.md §Perf): no_fsdp (replicate params
    over data: no gathers, full-grad all-reduce), compression=bf16|int8
    (quantized grad reduce), kv_quant (int8 KV cache)."""
    opts = opts or {}
    from .roofline import active_param_count
    b, s = shape.global_batch, shape.seq_len
    n_active = active_param_count(cfg)
    p_bytes, p_count = _param_bytes(cfg)
    data_ways = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
    model_ways = mesh_axes.get("model", 1)

    if shape.kind == "train":
        fwd = _forward_flops(cfg, b, s)
        flops = 4.0 * fwd if cfg.remat else 3.0 * fwd  # fwd+recompute+2bwd
        model_flops = 6.0 * n_active * b * s
        # HBM traffic: params (3 traversals per microbatch + optimizer),
        # layer activations (~8 passes incl. recompute), score matrices (3x)
        acts = _act_bytes_per_layer(cfg, b, s) * cfg.n_layers * 8
        h_sc = cfg.n_heads * (min(cfg.swa_window, s) if cfg.swa_window else s)
        scores = 3 * b * s * h_sc * F32 * (0.5 if not cfg.swa_window else 1.0)
        logits = 3 * b * s * cfg.vocab * F32
        hbm = p_bytes * (3 * microbatches + 6) + acts + scores + logits
        # collectives per chip: FSDP param all-gathers in COMPUTE dtype
        # (bf16 -- the master->bf16 cast happens before the cycle scan),
        # fwd+recompute+bwd per microbatch; grad RS/AG; TP all-reduces
        grad_bytes = {"bf16": BF16, "int8": 1}.get(
            opts.get("compression"), F32)
        fsdp = (0.0 if opts.get("no_fsdp")
                else 3 * microbatches * p_count * BF16)
        grads = 2 * p_count * grad_bytes
        tp_ar = (2 * 2 * microbatches * cfg.n_layers
                 * _act_bytes_per_layer(cfg, b // max(data_ways, 1), s))
        coll = fsdp + grads + tp_ar if model_ways > 1 or data_ways > 1 else 0.0
        detail = {"fwd_flops": fwd, "param_bytes": p_bytes,
                  "act_bytes": acts, "fsdp": fsdp, "grads": grads,
                  "tp_ar": tp_ar}
    elif shape.kind == "prefill":
        flops = _forward_flops(cfg, b, s)
        model_flops = 2.0 * n_active * b * s
        acts = _act_bytes_per_layer(cfg, b, s) * cfg.n_layers * 3
        hbm = p_bytes + acts + _cache_bytes(cfg, b, s)
        # weight-stationary serving: per-block activation all-reduces only
        coll = 4 * cfg.n_layers * _act_bytes_per_layer(
            cfg, max(b // max(data_ways, 1), 1), s)
        detail = {"param_bytes": p_bytes, "cache_bytes": _cache_bytes(cfg, b, s)}
    else:  # decode
        flops = _forward_flops(cfg, b, 1, causal=False)
        # attention reads the cache: add 2*b*1*S_eff*h*hd x2 einsums
        for i in range(cfg.n_layers):
            if cfg.layer_block_type(i) == "attn":
                s_eff = min(cfg.swa_window or s, s)
                flops += 2 * 2 * b * s_eff * cfg.n_heads * cfg.d_head
        model_flops = 2.0 * n_active * b
        cache = _cache_bytes(cfg, b, s, kv_quant=bool(opts.get("kv_quant")))
        hbm = p_bytes + cache  # read all params + whole cache once
        # weight-stationary: per-layer activation all-reduce (both axes)
        coll = 4 * cfg.n_layers * b * cfg.d_model * BF16
        detail = {"param_bytes": p_bytes, "cache_bytes": cache}

    return CellCost(flops=flops, hbm_bytes=hbm,
                    collective_bytes_per_chip=coll,
                    model_flops=model_flops, detail=detail)


def geostat_cell_cost(n: int, nb: int, diag_thick: int, *, chips: int,
                      off_update: str = "masked_full") -> CellCost:
    """Mixed-precision panel Cholesky + Matern cov-gen + solve.

    FLOPs are reported bf16-equivalent: fp32 MXU ops cost ~6x bf16 on v5e,
    so hi-band FLOPs are weighted x6 (this is exactly the paper's speedup
    mechanism on TPU).

    off_update waste factors over the useful n^3/3 (core/distributed.py):
      masked_full : every step updates the full (n, n) matrix -> 3.0x
      aligned     : rows pruned to the 16-tile boundary, full cols -> 1.5x
      square      : single-device banded engine, full m x m square -> 2.0x
      chunked     : exact lower trapezoid -> 1.0x
    """
    p = n // nb
    t = min(diag_thick, p)
    # band fraction of the trailing updates
    total_tiles = p * (p + 1) / 2
    band_tiles = t * p - t * (t - 1) / 2
    band_frac = band_tiles / total_tiles
    chol = n ** 3 / 3.0
    waste = {"masked_full": 3.0, "fori": 3.0, "aligned": 1.5,
             "square": 2.0, "chunked": 1.0}[off_update]
    lo_flops = chol * (1 - band_frac) * waste
    hi_flops = chol * band_frac * 6.0          # fp32 on MXU ~6x
    covgen = 50.0 * n * n                      # ~50 flops/entry Matern
    solve = 2.0 * n * n
    flops = lo_flops + hi_flops + covgen + solve
    # memory: off stored bf16, band fp32; each panel step rereads trailing
    off_bytes = n * n / 2 * BF16
    band_bytes = n * t * nb * F32
    hbm = off_bytes * p * 2 * (waste / 2 + 0.5) + band_bytes * p + covgen * 0
    # collectives: per step all-gather the panel column (both mesh axes)
    coll_panel = sum((n - (k + 1) * nb) * nb * BF16 * 2 for k in range(p))
    coll = coll_panel / max(chips ** 0.5, 1)   # gathered along one mesh row
    return CellCost(flops=flops, hbm_bytes=hbm,
                    collective_bytes_per_chip=coll,
                    model_flops=chol,
                    detail={"band_frac": band_frac, "p": p, "t": t,
                            "lo_flops": lo_flops, "hi_flops": hi_flops})


# MXU throughput weights relative to bf16 on v5e: fp32 ~6x, fp8 ~0.5x.
TIER_WEIGHT = {"hi": 6.0, "lo": 1.0, "lo2": 0.5}
_TIER_WEIGHT = TIER_WEIGHT  # back-compat alias

# Measured per-(kind, tier) kernel times, persisted by
# `python -m repro.obs calibrate` (see obs/calibrate.py): the StarPU-style
# alternative to the analytic weights above.  The committed table is a
# sample measured on the CI container's XLA CPU backend -- re-run the
# calibrator on your own hardware before trusting absolute numbers.
CALIBRATION_PATH = Path(__file__).resolve().parent / "calibration.json"

_UNSET = object()
_calibration_cache: object = _UNSET   # dict | None once resolved


def load_calibration(path=None) -> dict | None:
    """Read a calibration table; returns its costs dict or None if absent.

    With no `path`, reads (and caches) the persisted CALIBRATION_PATH
    table.  Costs map "KIND/tier" ("CONVERT" flat) -> measured
    microseconds; any key a DAG emits that the table lacks falls back to
    the analytic weight inside `task_virtual_cost`.
    """
    global _calibration_cache
    if path is not None:
        return json.loads(Path(path).read_text())["costs"]
    if _calibration_cache is _UNSET:
        if CALIBRATION_PATH.exists():
            _calibration_cache = json.loads(
                CALIBRATION_PATH.read_text())["costs"]
        else:
            _calibration_cache = None
    return _calibration_cache


def set_calibration(costs: dict | None) -> None:
    """Inject a cost table (tests / sweeps); None drops back to the file."""
    global _calibration_cache
    _calibration_cache = _UNSET if costs is None else dict(costs)

# Default virtual duration of a CONVERT (dlag2s/sconv2d) in the same
# bf16-equivalent nb^3 units as the compute weights below: an nb x nb tile
# moves ~nb^2 (BF16 + F32) bytes against ~nb^3-scale math, so at the nb the
# suites use (16-64) conversion lands well under one lo SYRK -- a quarter
# unit keeps it visible on the critical path without dominating it.
CONVERT_COST_UNITS = 0.25


def task_virtual_cost(task, *, convert_cost: float = CONVERT_COST_UNITS,
                      calibrated: bool = False,
                      table: dict | None = None) -> float:
    """Virtual duration of one `repro.analysis.dag.Task` for the simulated
    scheduler backend.

    Analytic path (default): tile-op FLOP units (POTRF 1/3, TRSM/SYRK 1,
    GEMM 2) scaled by the per-tier MXU throughput weight, in
    bf16-equivalent nb^3 units; CONVERTs cost a flat data-movement term.
    This is the same per-tier weighting `geostat_dag_cost` applies to
    whole-DAG totals, applied per task.

    Calibrated path (`calibrated=True`): measured microseconds from the
    persisted `launch/calibration.json` table (or an injected `table`),
    produced by `python -m repro.obs calibrate`.  Keys the table lacks
    fall back to the analytic weight -- the two unit systems differ, so a
    partially-calibrated table distorts relative priorities; the shipped
    calibrator measures every pair the engines emit precisely to avoid
    that.  Raises FileNotFoundError when no table exists at all rather
    than silently pricing an "analytically calibrated" schedule.
    """
    from ..analysis.dag import _FLOP_UNITS

    if calibrated:
        costs = table if table is not None else load_calibration()
        if costs is None:
            raise FileNotFoundError(
                f"calibrated=True but no calibration table at "
                f"{CALIBRATION_PATH}; run `python -m repro.obs calibrate` "
                "(or inject one via set_calibration)")
        key = "CONVERT" if task.kind == "CONVERT" \
            else f"{task.kind}/{task.tier}"
        if key in costs:
            return float(costs[key])
    if task.kind == "CONVERT":
        return float(convert_cost)
    return _FLOP_UNITS[task.kind] * TIER_WEIGHT[task.tier]


def geostat_dag_cost(n: int, nb: int, policy, *, chips: int,
                     variant: str = "tile") -> CellCost:
    """Exact-count sibling of geostat_cell_cost, fed by the static task DAG.

    geostat_cell_cost models the band split with a closed-form band_frac
    over an idealized n^3/3; this variant instead sums the POTRF/TRSM/
    SYRK/GEMM tasks the engine actually emits (repro.analysis.dag), so the
    per-tier mix, conversion traffic, and critical path are exact.  The
    same x6 fp32-on-MXU weighting maps them to bf16-equivalent FLOPs.
    """
    from ..analysis.dag import flop_report

    rep = flop_report(n, nb, policy, variant)
    flops = sum(rep[f"{t}_flops"] * w for t, w in _TIER_WEIGHT.items())
    # dlag2s/sconv2d traffic: one nb x nb tile read + write per conversion
    convert_bytes = rep["convert_tiles"] * nb * nb * (BF16 + F32)
    p = n // nb
    t = min(policy.diag_thick, p)
    off_bytes = n * n / 2 * BF16
    band_bytes = n * t * nb * F32
    hbm = off_bytes * p + band_bytes * p + convert_bytes
    coll_panel = sum((n - (k + 1) * nb) * nb * BF16 * 2 for k in range(p))
    coll = coll_panel / max(chips ** 0.5, 1)
    return CellCost(flops=flops, hbm_bytes=hbm,
                    collective_bytes_per_chip=coll,
                    model_flops=n ** 3 / 3.0,
                    detail={"hi_frac": rep["hi_frac"],
                            "lo_frac": rep["lo_frac"],
                            "lo2_frac": rep["lo2_frac"],
                            "total_flops": rep["total_flops"],
                            "critical_path_flops": rep["critical_path_flops"],
                            "critical_path_tasks": rep["critical_path_tasks"],
                            "convert_tiles": rep["convert_tiles"]})
