from .train_step import TrainConfig, init_train_state, make_train_step
