"""Training step factory: loss -> grads -> (compressed) -> AdamW.

Features (flags on TrainConfig):
  * bf16 compute / fp32 master weights
  * global-norm clipping + cosine schedule
  * microbatch gradient accumulation (sequential lax.scan over microbatches
    -- the standard way to fit global_batch=256 x 4096 tokens per step)
  * gradient compression with error feedback (runtime/compression.py)
  * remat is a model-config flag (ArchConfig.remat), applied per cycle
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.transformer import lm_loss
from ..optim import adamw
from ..runtime.compression import compress_with_feedback, init_residual


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1            # grad accumulation factor
    compression: str = "none"        # none | bf16 | int8
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"    # "bfloat16" halves Adam-m memory


def init_train_state(key, cfg: ArchConfig, tc: TrainConfig):
    from ..models.transformer import init_lm
    params, axes = init_lm(key, cfg)
    mdt = jnp.bfloat16 if tc.moment_dtype == "bfloat16" else jnp.float32
    state = {"params": params, "opt": adamw.init(params, moment_dtype=mdt),
             "data_step": jnp.zeros((), jnp.int32)}
    if tc.compression != "none":
        state["residual"] = init_residual(params)
    return state, axes


def make_train_step(cfg: ArchConfig, tc: TrainConfig):
    lr_fn = adamw.cosine_schedule(tc.peak_lr, tc.warmup, tc.total_steps)
    cdt = jnp.bfloat16 if tc.compute_dtype == "bfloat16" else jnp.float32

    def loss_fn(params, batch):
        # cast fp32 master -> compute dtype ONCE, before the cycle scan:
        # FSDP all-gathers then move bf16, not fp32 (halves the dominant
        # train collective term -- §Perf iteration "bf16 gathers")
        params_c = jax.tree.map(
            lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p, params)
        loss, parts = lm_loss(params_c, batch, cfg, compute_dtype=cdt)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if tc.microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % tc.microbatches == 0
                return x.reshape(tc.microbatches, b // tc.microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, parts), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_, g_acc, g)
                return (g_acc, l_acc + loss), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            (g_sum, l_sum), _ = jax.lax.scan(acc_fn, (zero_g, 0.0), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, g_sum)
            loss = l_sum / tc.microbatches
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            (loss, parts), grads = grad_fn(params, batch)

        new_state = dict(state)
        if tc.compression != "none":
            grads, new_state["residual"] = compress_with_feedback(
                grads, state["residual"], mode=tc.compression)

        new_params, new_opt, gnorm = adamw.update(
            params, grads, state["opt"], lr=lr_fn,
            weight_decay=tc.weight_decay, clip_norm=tc.clip_norm)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["data_step"] = state["data_step"] + 1
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": lr_fn(new_opt["step"]), **parts}
        return new_state, metrics

    return train_step
