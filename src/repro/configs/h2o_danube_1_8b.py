"""h2o-danube-1.8b [arXiv:2401.16818]: 24L d=2560 32H (kv=8) d_ff=6912
vocab 32000, llama+mistral mix with sliding-window attention."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_head=80,
    d_ff=6912, vocab=32000, swa_window=4096, rope_theta=1e4,
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512, swa_window=8,
                      remat=False)
