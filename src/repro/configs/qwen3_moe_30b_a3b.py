"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (kv=4)
MoE 128 experts top-8, expert d_ff=768, vocab 151936, qk_norm."""
from ..models.config import ArchConfig, MoESpec, register_arch

CONFIG = register_arch(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=0, vocab=151936, qk_norm=True, rope_theta=1e6,
    moe=MoESpec(n_experts=128, top_k=8, d_expert=768),
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, vocab=512,
                      moe=MoESpec(n_experts=8, top_k=2, d_expert=32),
                      remat=False)
