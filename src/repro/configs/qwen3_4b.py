"""qwen3-4b [hf:Qwen/Qwen3-4B]: 36L d=2560 32H (kv=8) d_ff=9728
vocab 151936, qk_norm."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=9728, vocab=151936, qk_norm=True, rope_theta=1e6,
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512, remat=False)
