"""llava-next-34b [hf:llava-hf/llava-v1.6-*]: 60L d=7168 56H (kv=8)
d_ff=20480 vocab 64000; anyres vision frontend stubbed as precomputed
patch embeddings (n_patches=2880 ~ 5x576 anyres tiles)."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000, frontend="vision_stub", n_patches=2880,
    rope_theta=1e6,
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512, n_patches=8,
                      remat=False)
