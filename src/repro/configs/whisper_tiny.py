"""whisper-tiny [arXiv:2212.04356]: enc-dec 4L d=384 6H d_ff=1536
vocab 51865; conv frontend stubbed (precomputed frame embeddings)."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_head=64,
    d_ff=1536, vocab=51865, enc_dec=True, n_enc_layers=4,
    n_enc_frames=1500, frontend="audio_stub", rope_theta=1e4,
))

SMOKE = CONFIG.scaled(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_head=16, d_ff=128, vocab=512,
                      n_enc_frames=32, remat=False)
