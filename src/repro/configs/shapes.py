"""Assigned input shapes and per-(arch x shape) input specs.

LM transformer shapes are seq_len x global_batch.  decode_*/long_* lower
`serve_step` (one new token against a KV cache of seq_len), NOT train_step.
long_500k requires sub-quadratic attention: run for ssm/hybrid/SWA archs,
skip for pure full-attention archs (recorded in DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.attention_is_subquadratic:
        return False, ("pure full-attention arch: 524288-token dense KV "
                       "decode is the quadratic regime this shape excludes "
                       "(DESIGN.md §9)")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> batch dict for train_step
    prefill-> (tokens, [frames|patches]) for the prefill lowering
    decode -> (cache, tokens, pos) for the decode lowering
    No device memory is allocated.
    """
    s = jax.ShapeDtypeStruct
    b, sl = shape.global_batch, shape.seq_len

    def token_batch():
        batch = {"tokens": s((b, sl), jnp.int32),
                 "labels": s((b, sl), jnp.int32)}
        if cfg.frontend == "vision_stub":
            # seq_len counts patches + text (DESIGN.md §9)
            n_text = sl - cfg.n_patches
            batch["tokens"] = s((b, n_text), jnp.int32)
            batch["labels"] = s((b, n_text), jnp.int32)
            batch["patches"] = s((b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.enc_dec:
            batch["frames"] = s((b, cfg.n_enc_frames, cfg.d_model),
                                jnp.float32)
        return batch

    if shape.kind == "train":
        return token_batch()

    if shape.kind == "prefill":
        batch = token_batch()
        batch.pop("labels")
        return batch

    # decode: cache of length seq_len + one token
    from ..models.decode import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, sl))
    return {
        "cache": cache,
        "tokens": s((b, 1), jnp.int32),
        "pos": s((), jnp.int32),
    }
