"""qwen3-32b [hf:Qwen/Qwen3-32B]: 64L d=5120 64H (kv=8) d_ff=25600
vocab 151936, qk_norm."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=25600, vocab=151936, qk_norm=True, rope_theta=1e6,
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512, remat=False)
