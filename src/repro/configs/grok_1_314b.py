"""grok-1-314b [hf:xai-org/grok-1; unverified]: 64L d=6144 48H (kv=8)
MoE 8 experts top-2, expert d_ff=32768, vocab 131072."""
from ..models.config import ArchConfig, MoESpec, register_arch

CONFIG = register_arch(ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=0, vocab=131072, rope_theta=1e4,
    moe=MoESpec(n_experts=8, top_k=2, d_expert=32768),
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                      d_head=16, vocab=512,
                      moe=MoESpec(n_experts=4, top_k=2, d_expert=64),
                      remat=False)
