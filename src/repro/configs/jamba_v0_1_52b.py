"""jamba-v0.1-52b [arXiv:2403.19887]: 32L d=4096 32H (kv=8) d_ff=14336,
Mamba:attention 7:1 interleave, MoE 16 experts top-2 on every other layer."""
from ..models.config import ArchConfig, MoESpec, register_arch

CONFIG = register_arch(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=65536, rope_theta=1e4,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe=MoESpec(n_experts=16, top_k=2, d_expert=14336, every=2, rem=1),
    ssm_expand=2, ssm_d_state=16, mamba_chunk=256,
))

SMOKE = CONFIG.scaled(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512, mamba_chunk=8,
                      moe=MoESpec(n_experts=4, top_k=2, d_expert=64,
                                  every=2, rem=1),
                      remat=False)
