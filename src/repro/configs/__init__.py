"""Assigned architecture registry: one module per architecture."""

from . import (
    grok_1_314b,
    h2o_danube_1_8b,
    jamba_v0_1_52b,
    llama3_2_1b,
    llava_next_34b,
    qwen3_32b,
    qwen3_4b,
    qwen3_moe_30b_a3b,
    whisper_tiny,
    xlstm_1_3b,
)
from .geostat import GEOSTAT_CONFIGS, GeostatConfig
from .shapes import SHAPES, ShapeSpec, cell_applicable, input_specs

_MODULES = (qwen3_moe_30b_a3b, grok_1_314b, whisper_tiny, qwen3_4b,
            llama3_2_1b, qwen3_32b, h2o_danube_1_8b, xlstm_1_3b,
            llava_next_34b, jamba_v0_1_52b)

ALL_ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKE_ARCHS = {m.CONFIG.name: m.SMOKE for m in _MODULES}

__all__ = ["ALL_ARCHS", "SMOKE_ARCHS", "SHAPES", "ShapeSpec",
           "cell_applicable", "input_specs", "GEOSTAT_CONFIGS",
           "GeostatConfig"]
