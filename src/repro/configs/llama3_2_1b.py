"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B]: 16L d=2048 32H (kv=8)
d_ff=8192 vocab 128256, tied embeddings, rope theta 5e5."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
    d_ff=8192, vocab=128256, rope_theta=5e5, tie_embeddings=True,
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512, remat=False)
