"""The paper's own workload: mixed-precision tile Cholesky MLE.

Production cells (dry-run rows alongside the 40 LM cells):
  geostat_500k : n=524288, nb=8192 (p=64 panels), band t=8 -> DP(~22%)
  geostat_1m   : n=1048576 (multi-pod), nb=16384 (p=64), band t=8
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GeostatConfig:
    name: str
    n: int
    nb: int
    diag_thick: int
    nu: float = 0.5
    off_update: str = "square"


GEOSTAT_CONFIGS = {
    "geostat_500k": GeostatConfig("geostat_500k", 524_288, 8_192, 8),
    "geostat_1m": GeostatConfig("geostat_1m", 1_048_576, 16_384, 8),
    "geostat_smoke": GeostatConfig("geostat_smoke", 512, 64, 2),
}
