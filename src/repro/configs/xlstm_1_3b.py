"""xlstm-1.3b [arXiv:2405.04517]: 48L d=2048 4H, alternating
sLSTM + mLSTM blocks, vocab 50304, no separate MLP (d_ff=0)."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_head=512,
    d_ff=0, vocab=50304, block_pattern=("mlstm", "slstm"),
    ssm_expand=2,
))

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_head=16, vocab=512, remat=False)
