"""Synthetic geostatistical data generation (ExaGeoStat's generator).

Mirrors the data generator described in the paper (Sec. VIII-B1) and in
Abdulah et al. 2018 [paper ref 32]:

  1. irregular 2-D locations: a sqrt(n) x sqrt(n) grid in (0, 1)^2 perturbed
     by uniform jitter (so locations are irregular but well-spread);
  2. measurements Z = L eps with Sigma(theta0) = L L^T from the Matern
     kernel and eps ~ N(0, I).

Also provides the WRF-like "wind speed" simulator used for the Table-I
reproduction: since the real Middle-East WRF dataset is not redistributable
(and there is no network access), we *simulate* a field per region with the
Matern parameters the paper reports in Table I, then re-estimate them --
validating estimator consistency exactly the way the paper's Table I does.
This substitution is recorded in DESIGN.md ("Changed assumptions").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .matern import matern_covariance
from .ordering import ORDERINGS, apply_ordering


class Dataset(NamedTuple):
    locs: jnp.ndarray   # (n, 2)
    z: jnp.ndarray      # (n,)
    theta0: jnp.ndarray  # generating parameters (3,)
    metric: str


def random_locations(key, n: int, *, lo: float = 0.0, hi: float = 1.0,
                     dtype=jnp.float32):
    """Irregular perturbed-grid locations in (lo, hi)^2 (ExaGeoStat style)."""
    m = int(jnp.ceil(jnp.sqrt(n)))
    xs, ys = jnp.meshgrid(jnp.arange(m), jnp.arange(m), indexing="ij")
    grid = jnp.stack([xs.reshape(-1), ys.reshape(-1)], axis=-1).astype(dtype)
    jitter = jax.random.uniform(key, (m * m, 2), minval=-0.4, maxval=0.4,
                                dtype=dtype)
    locs = (grid + 0.5 + jitter) / m  # in (0, 1)^2
    locs = locs[:n]
    return lo + locs * (hi - lo)


def simulate_field(key, locs, theta0, *, nu_static=None, metric="euclidean",
                   nugget: float = 0.0, jitter: float = 1e-8):
    """Draw Z ~ N(0, Sigma(theta0)) exactly via dense Cholesky."""
    n = locs.shape[0]
    cov = matern_covariance(locs, locs, jnp.asarray(theta0), nu_static=nu_static,
                            metric=metric, nugget=nugget)
    cov = cov + jitter * jnp.eye(n, dtype=cov.dtype)
    chol = jnp.linalg.cholesky(cov)
    eps = jax.random.normal(key, (n,), dtype=cov.dtype)
    return chol @ eps


def make_dataset(key, n: int, theta0, *, nu_static=None, ordering: str = "morton",
                 metric: str = "euclidean", nugget: float = 0.0) -> Dataset:
    """Locations + field draw + space-filling-curve ordering, one call."""
    k_loc, k_field = jax.random.split(key)
    locs = random_locations(k_loc, n)
    z = simulate_field(k_field, locs, theta0, nu_static=nu_static, metric=metric,
                       nugget=nugget)
    perm = ORDERINGS[ordering](locs)
    locs, z = apply_ordering(locs, z, perm)
    return Dataset(locs=locs, z=z, theta0=jnp.asarray(theta0), metric=metric)


# Paper Sec. VIII-D1: three correlation levels for the synthetic study.
CORRELATION_LEVELS = {
    "weak": jnp.array([1.0, 0.03, 0.5]),
    "medium": jnp.array([1.0, 0.10, 0.5]),
    "strong": jnp.array([1.0, 0.30, 0.5]),
}


# Table-I Matern parameters per wind-speed region (theta1, theta2, theta3).
# R1's row is unreadable in the paper scan; we use values interpolated from
# R2-R4 (flagged in DESIGN.md).  theta2 is on the haversine-degrees scale.
WIND_REGIONS = {
    "R1": jnp.array([11.1, 24.0, 1.30]),
    "R2": jnp.array([12.533, 27.603, 1.270]),
    "R3": jnp.array([10.813, 19.196, 1.417]),
    "R4": jnp.array([12.441, 19.733, 1.119]),
}


def wind_like_dataset(key, region: str, n: int, *, ordering: str = "morton") -> Dataset:
    """WRF-like wind-speed field for one Arabian-Peninsula subregion.

    Locations are drawn on a lon/lat box roughly matching one quadrant of
    the paper's Fig. 3 domain; distances are haversine (degrees).
    """
    theta0 = WIND_REGIONS[region]
    boxes = {  # (lon_lo, lon_hi, lat_lo, lat_hi) quadrants of [30,60]x[10,35]
        "R1": (30.0, 45.0, 22.5, 35.0),
        "R2": (45.0, 60.0, 22.5, 35.0),
        "R3": (30.0, 45.0, 10.0, 22.5),
        "R4": (45.0, 60.0, 10.0, 22.5),
    }
    lon_lo, lon_hi, lat_lo, lat_hi = boxes[region]
    k_loc, k_field = jax.random.split(key)
    unit = random_locations(k_loc, n)
    locs = jnp.stack(
        [lon_lo + unit[:, 0] * (lon_hi - lon_lo), lat_lo + unit[:, 1] * (lat_hi - lat_lo)],
        axis=-1,
    )
    z = simulate_field(k_field, locs, theta0, metric="haversine", jitter=1e-6)
    # order on the unit-normalized coords
    perm = ORDERINGS[ordering]((locs - locs.min(0)) / (locs.max(0) - locs.min(0)))
    locs, z = apply_ordering(locs, z, perm)
    return Dataset(locs=locs, z=z, theta0=theta0, metric="haversine")
