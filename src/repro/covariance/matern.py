"""Matern covariance function (paper Eq. 1) in pure JAX.

C(r; theta) = theta1 * 2^(1-nu)/Gamma(nu) * (r/theta2)^nu * K_nu(r/theta2)

with theta = (theta1: variance, theta2: spatial range, theta3 = nu: smoothness).

K_nu is the modified Bessel function of the second kind.  It is not provided
by jax.scipy.special, so we implement it here:

  * closed forms for the half-integer smoothnesses nu in {0.5, 1.5, 2.5}
    (exponential x polynomial) -- these are the cases used for the paper's
    synthetic study and are cheap enough to live inside Pallas kernels;
  * a general-nu path (needed for the real-data regime, nu-hat ~ 1.1-1.4)
    following Numerical Recipes `bessik`: Temme's series for x <= 2 and the
    Steed/CF2 continued fraction for x > 2, then masked upward recurrence.
    All loops have static trip counts so the function jits/vmaps/grads.

Validated against scipy.special.kv in tests/test_matern.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

# Static bounds: series/CF iteration counts and max smoothness.
_MAXIT = 80
_NU_MAX_RECURRENCE = 12  # supports nu < 11.5; geostatistics uses nu < 5
_EULER_GAMMA = 0.5772156649015329

# Chebyshev coefficients (Numerical Recipes `beschb`) for
#   gam1(mu) ~ (1/Gamma(1-mu) - 1/Gamma(1+mu)) / (2 mu)
#   gam2(mu) ~ (1/Gamma(1-mu) + 1/Gamma(1+mu)) / 2        for |mu| <= 1/2.
_C1 = (
    -1.142022680371168e0,
    6.5165112670737e-3,
    3.087090173086e-4,
    -3.4706269649e-6,
    6.9437664e-9,
    3.67795e-11,
    -1.356e-13,
)
_C2 = (
    1.843740587300905e0,
    -7.68528408447867e-2,
    1.2719271366546e-3,
    -4.9717367042e-6,
    -3.31261198e-8,
    2.423096e-10,
    -1.702e-13,
    -1.49e-15,
)


def _chebev(coeffs: tuple, x):
    """Chebyshev series evaluation on [-1, 1] (Clenshaw).

    coeffs stay Python floats (weak-typed) so the series runs at x's dtype
    -- including fp64 under jax.experimental.enable_x64.
    """
    d = jnp.zeros_like(x)
    dd = jnp.zeros_like(x)
    x2 = 2.0 * x
    for c in coeffs[::-1][:-1]:
        d, dd = x2 * d - dd + c, d
    return x * d - dd + 0.5 * coeffs[0]


def _beschb(mu):
    """gam1, gam2, gampl=1/Gamma(1+mu), gammi=1/Gamma(1-mu) for |mu|<=0.5."""
    xx = 8.0 * mu * mu - 1.0
    gam1 = _chebev(_C1, xx)
    gam2 = _chebev(_C2, xx)
    gampl = gam2 - mu * gam1
    gammi = gam2 + mu * gam1
    return gam1, gam2, gampl, gammi


def _kv_temme_series(nu_frac, x):
    """K_mu(x), K_{mu+1}(x) for x <= 2, mu = nu_frac in [-0.5, 0.5]."""
    mu = nu_frac
    x = jnp.minimum(x, 2.0)  # branch-safe clamp (selection happens outside)
    pimu = jnp.pi * mu
    fact = jnp.where(jnp.abs(pimu) < 1e-7, 1.0, pimu / jnp.sin(jnp.where(jnp.abs(pimu) < 1e-7, 1.0, pimu)))
    d = -jnp.log(x / 2.0)
    e = mu * d
    fact2 = jnp.where(jnp.abs(e) < 1e-7, 1.0, jnp.sinh(e) / jnp.where(jnp.abs(e) < 1e-7, 1.0, e))
    gam1, gam2, gampl, gammi = _beschb(mu)
    ff = fact * (gam1 * jnp.cosh(e) + gam2 * fact2 * d)
    ssum = ff
    e = jnp.exp(e)
    p = 0.5 * e / gampl
    q = 0.5 / (e * gammi)
    c = jnp.ones_like(x)
    dd = x * x / 4.0
    sum1 = p

    def body(i, carry):
        ff, ssum, sum1, c, p, q = carry
        fi = i.astype(x.dtype)
        ff = (fi * ff + p + q) / (fi * fi - mu * mu)
        c = c * dd / fi
        p = p / (fi - mu)
        q = q / (fi + mu)
        ssum = ssum + c * ff
        sum1 = sum1 + c * (p - fi * ff)
        return ff, ssum, sum1, c, p, q

    carry = (ff, ssum, sum1, c, p, q)
    carry = jax.lax.fori_loop(1, _MAXIT + 1, body, carry)
    _, ssum, sum1, _, _, _ = carry
    rkmu = ssum
    rk1 = sum1 * (2.0 / x)
    return rkmu, rk1


def _kv_cf2(nu_frac, x):
    """K_mu(x), K_{mu+1}(x) for x > 2 via Steed's CF2 (NR bessik)."""
    mu = nu_frac
    x = jnp.maximum(x, 2.0)  # branch-safe clamp
    b = 2.0 * (1.0 + x)
    d = 1.0 / b
    h = d
    delh = d
    q1 = jnp.zeros_like(x)
    q2 = jnp.ones_like(x)
    a1 = 0.25 - mu * mu
    q = a1 * jnp.ones_like(x)
    c = a1 * jnp.ones_like(x)
    a = -a1 * jnp.ones_like(x)
    s = 1.0 + q * delh

    eps = jnp.finfo(x.dtype).eps
    done0 = jnp.zeros_like(x, dtype=bool)

    def body(i, carry):
        a, b, c, d, h, delh, q, q1, q2, s, done = carry
        fi = i.astype(x.dtype)
        a_n = a - 2.0 * (fi - 1.0)
        c_n = -a_n * c / fi
        qnew = (q1 - b * q2) / a_n
        q_n = q + c_n * qnew
        b_n = b + 2.0
        d_n = 1.0 / (b_n + a_n * d)
        delh_n = (b_n * d_n - 1.0) * delh
        h_n = h + delh_n
        dels = q_n * delh_n
        s_n = s + dels
        # freeze all state after convergence: running a fixed-trip-count
        # loop past convergence overflows q1/q2 in fp32 (NR breaks instead)
        sel = lambda new, old: jnp.where(done, old, new)
        new_done = done | (jnp.abs(dels) < jnp.abs(s_n) * eps)
        return (sel(a_n, a), sel(b_n, b), sel(c_n, c), sel(d_n, d),
                sel(h_n, h), sel(delh_n, delh), sel(q_n, q),
                sel(q2, q1), sel(qnew, q2), sel(s_n, s), new_done)

    carry = (a, b, c, d, h, delh, q, q1, q2, s, done0)
    carry = jax.lax.fori_loop(2, _MAXIT + 1, body, carry)
    a, b, c, d, h, delh, q, q1, q2, s, _ = carry
    h = a1 * h
    rkmu = jnp.sqrt(jnp.pi / (2.0 * x)) * jnp.exp(-x) / s
    rk1 = rkmu * (mu + x + 0.5 - h) / x
    return rkmu, rk1


def kv(nu, x):
    """Modified Bessel function of the second kind K_nu(x), elementwise.

    nu: scalar or array broadcastable against x (may be traced), nu >= 0,
        nu < _NU_MAX_RECURRENCE - 0.5.
    x:  array, x > 0.  Gradients flow through both arguments' jnp ops.
    """
    nu = jnp.asarray(nu)
    x = jnp.asarray(x)
    dtype = jnp.result_type(nu.dtype, x.dtype, jnp.float32)
    nu = nu.astype(dtype)
    x = jnp.maximum(x.astype(dtype), jnp.finfo(dtype).tiny)

    nl = jnp.floor(nu + 0.5)  # number of upward-recurrence steps
    mu = nu - nl  # fractional part in [-0.5, 0.5]

    small = x <= 2.0
    rkmu_s, rk1_s = _kv_temme_series(mu, x)
    rkmu_l, rk1_l = _kv_cf2(mu, x)
    rkmu = jnp.where(small, rkmu_s, rkmu_l)
    rk1 = jnp.where(small, rk1_s, rk1_l)

    # Masked upward recurrence K_{mu+i+1} = 2(mu+i)/x K_{mu+i} + K_{mu+i-1}.
    xi2 = 2.0 / x

    def rec(i, carry):
        rkmu, rk1 = carry
        fi = i.astype(dtype)
        take = fi <= nl
        rktemp = (mu + fi) * xi2 * rk1 + rkmu
        rkmu = jnp.where(take, rk1, rkmu)
        rk1 = jnp.where(take, rktemp, rk1)
        return rkmu, rk1

    rkmu, rk1 = jax.lax.fori_loop(1, _NU_MAX_RECURRENCE, rec, (rkmu, rk1))
    return rkmu


def _matern_half_integer(r_over_rho, nu: float):
    """Closed-form 2^(1-nu)/Gamma(nu) x^nu K_nu(x) for half-integer nu."""
    x = r_over_rho
    if nu == 0.5:
        return jnp.exp(-x)
    if nu == 1.5:
        return (1.0 + x) * jnp.exp(-x)
    if nu == 2.5:
        return (1.0 + x + x * x / 3.0) * jnp.exp(-x)
    raise ValueError(f"no closed form for nu={nu}")


HALF_INTEGER_NUS = (0.5, 1.5, 2.5)


def matern(r, theta, *, nu_static: float | None = None):
    """Matern covariance C(r; theta), paper Eq. (1).

    r: distances (any shape), theta = (theta1, theta2, theta3) or a stacked
      (..., 3) batch of parameter vectors: leading axes of theta broadcast
      against r, producing one covariance per candidate theta (the batched
      likelihood engine relies on this).
    nu_static: if one of HALF_INTEGER_NUS, use the closed form and IGNORE
      theta[..., 2] (the caller promises theta3 == nu_static); otherwise the
      general Bessel path with traced smoothness theta[..., 2] is used.
    """
    theta = jnp.asarray(theta)
    r = jnp.asarray(r)
    # reshape each parameter to (batch..., 1, ..., 1) so it broadcasts
    # against r regardless of r's rank
    batch = theta.shape[:-1]

    def param(i):
        return theta[..., i].reshape(batch + (1,) * r.ndim)

    theta1, theta2 = param(0), param(1)
    x = r / theta2
    if nu_static is not None:
        corr = _matern_half_integer(x, float(nu_static))
        return theta1 * jnp.where(r == 0.0, 1.0, corr)

    nu = param(2)
    xs = jnp.maximum(x, 1e-30)  # keep kv's domain valid at r == 0
    lognorm = (1.0 - nu) * jnp.log(2.0) - gammaln(nu)
    corr = jnp.exp(lognorm + nu * jnp.log(xs)) * kv(nu, xs)
    return theta1 * jnp.where(r == 0.0, 1.0, corr)


def pairwise_distance(locs_a, locs_b, *, metric: str = "euclidean"):
    """Pairwise distance matrix between two (n, 2) location sets.

    metric: "euclidean" (synthetic study, unit square) or "haversine"
    (real datasets on lon/lat degrees; great-circle distance in degrees,
    matching ExaGeoStat's use of the haversine formula [paper ref 31]).
    """
    if metric == "euclidean":
        d2 = jnp.sum((locs_a[:, None, :] - locs_b[None, :, :]) ** 2, axis=-1)
        return jnp.sqrt(jnp.maximum(d2, 0.0))
    if metric == "haversine":
        lon_a, lat_a = jnp.deg2rad(locs_a[:, 0]), jnp.deg2rad(locs_a[:, 1])
        lon_b, lat_b = jnp.deg2rad(locs_b[:, 0]), jnp.deg2rad(locs_b[:, 1])
        dlat = lat_a[:, None] - lat_b[None, :]
        dlon = lon_a[:, None] - lon_b[None, :]
        h = (
            jnp.sin(dlat / 2.0) ** 2
            + jnp.cos(lat_a)[:, None] * jnp.cos(lat_b)[None, :] * jnp.sin(dlon / 2.0) ** 2
        )
        h = jnp.clip(h, 0.0, 1.0)
        # 2 R asin(sqrt(h)); report in "degrees" (R = 180/pi) so theta2 is
        # on the same scale as the paper's Table I estimates.
        return 2.0 * (180.0 / jnp.pi) * jnp.arcsin(jnp.sqrt(h))
    raise ValueError(f"unknown metric {metric!r}")


def matern_covariance(locs_a, locs_b, theta, *, nu_static: float | None = None,
                      metric: str = "euclidean", nugget: float = 0.0):
    """Dense covariance block Sigma_ab with optional nugget on the diagonal.

    theta may carry leading batch axes (see `matern`); the result is then a
    (..., n_a, n_b) stack of covariance blocks.
    """
    d = pairwise_distance(locs_a, locs_b, metric=metric)
    cov = matern(d, theta, nu_static=nu_static)
    if nugget:
        n = min(cov.shape[-2], cov.shape[-1])
        idx = jnp.arange(n)
        cov = cov.at[..., idx, idx].add(nugget)
    return cov
