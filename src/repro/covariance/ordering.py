"""Spatial location orderings.

The paper's mixed-precision banding assumes "an appropriate ordering" of the
spatial locations so that correlation decays with tile-index distance.
ExaGeoStat uses Morton (Z-order); we provide Morton and Hilbert (the latter
has strictly better locality, which lets a *thinner* double-precision band
reach the same statistical accuracy -- evaluated as a beyond-paper ablation
in EXPERIMENTS.md).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def _part1by1(x):
    """Spread the low 16 bits of x over even bit positions (jnp-friendly)."""
    x = x & 0x0000FFFF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def morton_key(locs, bits: int = 16):
    """Morton (Z-order) key per location. locs: (n, 2) in [0, 1)^2."""
    locs = jnp.asarray(locs)
    scale = (1 << bits) - 1
    q = jnp.clip((locs * scale).astype(jnp.uint32), 0, scale)
    return _part1by1(q[:, 0]) | (_part1by1(q[:, 1]) << 1)


def morton_order(locs, bits: int = 16):
    """Permutation that sorts locations along the Morton curve."""
    return jnp.argsort(morton_key(locs, bits))


def hilbert_key_np(locs: np.ndarray, bits: int = 16) -> np.ndarray:
    """Hilbert-curve key (host-side numpy; ordering is a preprocessing step).

    Classic xy -> d conversion with bitwise rotations, vectorized over n.
    """
    locs = np.asarray(locs, dtype=np.float64)
    side = 1 << bits
    x = np.clip((locs[:, 0] * side).astype(np.uint64), 0, side - 1)
    y = np.clip((locs[:, 1] * side).astype(np.uint64), 0, side - 1)
    rx = np.zeros_like(x)
    ry = np.zeros_like(y)
    d = np.zeros_like(x)
    s = np.uint64(side // 2)
    while s > 0:
        rx = ((x & s) > 0).astype(np.uint64)
        ry = ((y & s) > 0).astype(np.uint64)
        d += s * s * ((3 * rx) ^ ry)
        # rotate quadrant: if ry == 0 { if rx == 1 mirror; swap x <-> y }
        flip = (ry == 0) & (rx == 1)
        x = np.where(flip, s - 1 - x, x)
        y = np.where(flip, s - 1 - y, y)
        swap = ry == 0
        x, y = np.where(swap, y, x), np.where(swap, x, y)
        s = np.uint64(s // 2)
    return d


def hilbert_order(locs, bits: int = 16):
    """Permutation that sorts locations along the Hilbert curve."""
    key = hilbert_key_np(np.asarray(locs), bits)
    return jnp.asarray(np.argsort(key, kind="stable"))


def apply_ordering(locs, z, perm):
    """Reorder locations and observations with the same permutation."""
    perm = jnp.asarray(perm)
    return jnp.asarray(locs)[perm], (None if z is None else jnp.asarray(z)[perm])


ORDERINGS = {
    "morton": morton_order,
    "hilbert": hilbert_order,
    "none": lambda locs, bits=16: jnp.arange(np.asarray(locs).shape[0]),
}
