from .matern import (
    HALF_INTEGER_NUS,
    kv,
    matern,
    matern_covariance,
    pairwise_distance,
)
from .generator import (
    CORRELATION_LEVELS,
    WIND_REGIONS,
    Dataset,
    make_dataset,
    random_locations,
    simulate_field,
    wind_like_dataset,
)
from .ordering import ORDERINGS, apply_ordering, hilbert_order, morton_order

__all__ = [
    "HALF_INTEGER_NUS", "kv", "matern", "matern_covariance", "pairwise_distance",
    "CORRELATION_LEVELS", "WIND_REGIONS", "Dataset", "make_dataset",
    "random_locations", "simulate_field", "wind_like_dataset",
    "ORDERINGS", "apply_ordering", "hilbert_order", "morton_order",
]
