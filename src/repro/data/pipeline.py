"""Deterministic, resumable, host-sharded synthetic data pipeline.

Counter-based generation: batch(step) is a pure function of (seed, step,
process_index), so (a) every host generates exactly its own shard with no
coordination, (b) restoring `data_step` from a checkpoint resumes the
stream exactly (fault tolerance), and (c) elastic re-sharding (different
host count after restart) re-partitions the same logical stream.

A FileSource with the same interface documents where a real corpus reader
plugs in (tokenized flat-array memmap); the synthetic source is the default
for all tests/benchmarks in this offline container.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    n_processes: int = 1
    process_index: int = 0


class SyntheticTokenSource:
    """Markov-ish synthetic token stream with learnable structure.

    Tokens follow t_{i+1} = (a * t_i + noise) mod vocab with per-sequence
    coefficients, so a real LM can actually reduce loss on it (used by the
    end-to-end training example to show convergence)."""

    def __init__(self, cfg: ArchConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        assert dc.global_batch % dc.n_processes == 0
        self.local_batch = dc.global_batch // dc.n_processes

    def batch_at(self, step: int):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.dc.seed), step),
            self.dc.process_index)
        b, s, v = self.local_batch, self.dc.seq_len, self.cfg.vocab
        k1, k2, k3 = jax.random.split(key, 3)
        a = jax.random.randint(k1, (b, 1), 1, 8)
        t0 = jax.random.randint(k2, (b, 1), 0, v)
        noise = jax.random.randint(k3, (b, s + 1), 0, 3)
        idx = jnp.arange(s + 1)[None, :]
        stream = (t0 + a * idx + noise) % v
        batch = {"tokens": stream[:, :-1].astype(jnp.int32),
                 "labels": stream[:, 1:].astype(jnp.int32)}
        if self.cfg.frontend == "vision_stub":
            kp = jax.random.fold_in(key, 17)
            batch["patches"] = jax.random.normal(
                kp, (b, self.cfg.n_patches, self.cfg.d_model), jnp.float32)
        if self.cfg.enc_dec:
            kf = jax.random.fold_in(key, 23)
            batch["frames"] = jax.random.normal(
                kf, (b, self.cfg.n_enc_frames, self.cfg.d_model), jnp.float32)
        return batch


class FileSource:
    """Memmap-backed tokenized corpus reader (same interface).

    Expects a flat .npy of int32 tokens; step/process determinism comes
    from strided offsets, so resume/elastic semantics match the synthetic
    source."""

    def __init__(self, cfg: ArchConfig, dc: DataConfig, path: str):
        self.cfg, self.dc = cfg, dc
        self.data = np.load(path, mmap_mode="r")
        self.local_batch = dc.global_batch // dc.n_processes

    def batch_at(self, step: int):
        b, s = self.local_batch, self.dc.seq_len
        span = s + 1
        base = (step * self.dc.global_batch
                + self.dc.process_index * b) * span
        rows = [np.asarray(self.data[(base + i * span) % (len(self.data) - span):]
                           [:span]) for i in range(b)]
        arr = jnp.asarray(np.stack(rows), jnp.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
