from .pipeline import DataConfig, FileSource, SyntheticTokenSource
