"""In-house AdamW with decoupled weight decay and global-norm clipping.

fp32 master weights and moments; gradients may arrive bf16 (cast up).
State is a plain pytree so checkpointing/resharding handles it like params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, *, moment_dtype=jnp.float32):
    """moment_dtype=bf16 halves first-moment memory (the production lever
    that fits grok-1-314b fp32 master + Adam inside 16 GB/chip)."""
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
           weight_decay=0.1, clip_norm=1.0):
    """One AdamW step. lr may be a scalar or a step -> lr callable."""
    step = state["step"] + 1
    if callable(lr):
        lr = lr(step)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, clip_norm)

    m = jax.tree.map(lambda m_, g: (b1 * m_.astype(jnp.float32)
                                    + (1 - b1) * g).astype(m_.dtype),
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mhat = m_.astype(jnp.float32) / bc1
        vhat = v_ / bc2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
