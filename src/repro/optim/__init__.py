from . import adamw
from .adamw import cosine_schedule, global_norm
