"""Pure-jnp oracle for the in-VMEM potrf kernel."""

import jax.numpy as jnp


def potrf_ref(a):
    return jnp.linalg.cholesky(a.astype(jnp.float32)).astype(a.dtype)
