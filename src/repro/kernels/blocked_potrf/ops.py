"""Jitted public wrapper for the in-VMEM potrf kernel."""

from functools import partial

import jax

from .blocked_potrf import potrf_pallas


@partial(jax.jit, static_argnames=("interpret",))
def potrf(a, *, interpret: bool = True):
    return potrf_pallas(a, interpret=interpret)
