"""Pallas TPU kernel: in-VMEM Cholesky of one diagonal tile (dpotrf).

The diagonal-tile factorization is the only inherently sequential tile op
in Algorithm 1.  It is tiny (nb^3/3 vs the n^3/3 total) but sits on the
critical path, so it should run entirely out of VMEM with no HBM round
trips.  This kernel holds the (nb x nb) tile as a value in
registers/VMEM and runs a right-looking column sweep: per column j, a
rsqrt-scaled column normalization followed by a rank-1 MXU update of the
trailing part.  Masks (broadcasted iota) replace dynamic triangular shapes.

nb <= 512 keeps the tile + rank-1 temporaries well under the ~16 MB VMEM
budget (512^2 * 4 B = 1 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _potrf_kernel(a_ref, out_ref):
    a = a_ref[...].astype(jnp.float32)
    a = a.reshape(a.shape[-2:])  # squeeze batched (1, n, n) blocks
    n = a.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def body(j, carry):
        a, l = carry
        dj = jax.lax.dynamic_slice(a, (j, j), (1, 1))          # (1, 1)
        inv = jax.lax.rsqrt(jnp.maximum(dj, 1e-30))
        col = jax.lax.dynamic_slice(a, (0, j), (n, 1)) * inv   # (n, 1)
        below = rows > j
        col_below = jnp.where(below, col, 0.0)
        col_full = jnp.where(rows == j, jnp.sqrt(jnp.maximum(dj, 0.0)), col_below)
        l = jax.lax.dynamic_update_slice(l, col_full, (0, j))
        # rank-1 trailing update (MXU): A -= c c^T on the strictly-below part
        a = a - jnp.dot(col_below, col_below.T,
                        preferred_element_type=jnp.float32)
        return a, l

    _, l = jax.lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    out_ref[...] = l.reshape(out_ref.shape).astype(out_ref.dtype)


def potrf_pallas(a, *, interpret: bool = True):
    """Cholesky factor (lower) of a single SPD tile, fully in VMEM."""
    n = a.shape[-1]
    assert a.shape[-2] == n
    if a.ndim == 2:
        return pl.pallas_call(
            _potrf_kernel,
            out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
            in_specs=[pl.BlockSpec((n, n), lambda: (0, 0))],
            out_specs=pl.BlockSpec((n, n), lambda: (0, 0)),
            interpret=interpret,
        )(a)
    # batched tiles: grid over the leading dim
    b = a.shape[0]
    return pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct((b, n, n), a.dtype),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(a)
