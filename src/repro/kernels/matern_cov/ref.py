"""Pure-jnp oracle for the Matern covariance tile kernel."""

import jax.numpy as jnp

from ...covariance.matern import HALF_INTEGER_NUS, matern_covariance


def matern_cov_ref(locs_a, locs_b, theta, *, nu: float, out_dtype=jnp.float32):
    theta = jnp.asarray(theta, jnp.float32)
    theta = jnp.array([theta[0], theta[1], jnp.float32(nu)])
    nu_static = nu if nu in HALF_INTEGER_NUS else None
    return matern_covariance(locs_a, locs_b, theta,
                             nu_static=nu_static).astype(out_dtype)
