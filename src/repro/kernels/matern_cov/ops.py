"""Jitted public wrapper for the Matern covariance kernel.

Half-integer smoothness dispatches to the Pallas kernel; general smoothness
falls back to the pure-jnp Bessel path (the Temme/CF2 series is VPU-heavy
and not worth a hand-written kernel -- cov-gen is < 1% of MLE FLOPs there).
"""

from functools import partial

import jax
import jax.numpy as jnp

from ...covariance.matern import HALF_INTEGER_NUS
from .matern_cov import matern_cov_pallas
from .ref import matern_cov_ref


@partial(jax.jit, static_argnames=("nu", "bm", "bn", "out_dtype", "interpret"))
def matern_cov(locs_a, locs_b, theta, *, nu: float, bm: int = 128, bn: int = 128,
               out_dtype=jnp.float32, interpret: bool = True):
    if nu in HALF_INTEGER_NUS:
        return matern_cov_pallas(locs_a, locs_b, theta, nu=nu, bm=bm, bn=bn,
                                 out_dtype=out_dtype, interpret=interpret)
    return matern_cov_ref(locs_a, locs_b, theta, nu=nu, out_dtype=out_dtype)
