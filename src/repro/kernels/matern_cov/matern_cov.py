"""Pallas TPU kernel: tiled Matern covariance generation.

Covariance generation is ExaGeoStat's first computational phase (O(n^2)
kernel evaluations).  On TPU we tile the (n x n) output into (bm x bn)
VMEM blocks; the pairwise squared distance is computed MXU-style as
|xi|^2 + |xj|^2 - 2 xi.xj^T (one small matmul per tile) and the Matern
closed form (half-integer smoothness) is evaluated on the VPU.

General (Bessel) smoothness falls back to the pure-jnp oracle in ops.py.
Validated in interpret mode against ref.py (tests/test_kernels_matern.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matern_tile_kernel(theta_ref, locs_i_ref, locs_j_ref, out_ref, *, nu: float):
    th1 = theta_ref[0, 0]
    rho = theta_ref[0, 1]
    xi = locs_i_ref[...].astype(jnp.float32)          # (bm, 2)
    xj = locs_j_ref[...].astype(jnp.float32)          # (bn, 2)
    ni = jnp.sum(xi * xi, axis=-1, keepdims=True)     # (bm, 1)
    nj = jnp.sum(xj * xj, axis=-1, keepdims=True)     # (bn, 1)
    cross = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(ni + nj.T - 2.0 * cross, 0.0)
    r = jnp.sqrt(d2)
    x = r / rho
    if nu == 0.5:
        corr = jnp.exp(-x)
    elif nu == 1.5:
        corr = (1.0 + x) * jnp.exp(-x)
    elif nu == 2.5:
        corr = (1.0 + x + x * x / 3.0) * jnp.exp(-x)
    else:  # pragma: no cover - guarded in ops.py
        raise ValueError(f"kernel supports half-integer nu, got {nu}")
    out_ref[...] = (th1 * jnp.where(r == 0.0, 1.0, corr)).astype(out_ref.dtype)


def matern_cov_pallas(locs_a, locs_b, theta, *, nu: float, bm: int = 128,
                      bn: int = 128, out_dtype=jnp.float32,
                      interpret: bool = True):
    """Tiled Matern covariance: (m, 2) x (n, 2) -> (m, n).

    bm/bn: VMEM tile sizes (128-aligned for the MXU on real TPU).
    interpret=True executes the kernel body on CPU for validation.
    """
    m = locs_a.shape[0]
    n = locs_b.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    theta2d = jnp.reshape(jnp.asarray(theta, jnp.float32)[:3], (1, 3))
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_matern_tile_kernel, nu=nu),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(theta2d, locs_a, locs_b)
