"""Pure-jnp oracle for the banded mixed-precision SYRK kernel."""

import numpy as np
import jax.numpy as jnp


def mp_syrk_ref(p, *, band_blocks: int, bm: int = 128, bk: int = 128,
                hi_dtype=jnp.float32, lo_dtype=jnp.bfloat16,
                accum_dtype=jnp.float32):
    """Blockwise reference with identical precision routing and k-loop
    rounding order as the kernel."""
    m, kdim = p.shape
    nb = m // bm
    nk = kdim // bk
    out = np.zeros((m, m), dtype=np.float32)
    p = np.asarray(p, np.float32)
    for i in range(nb):
        for j in range(nb):
            acc = np.zeros((bm, bm), np.float32)
            for k in range(nk):
                a = p[i * bm:(i + 1) * bm, k * bk:(k + 1) * bk]
                b = p[j * bm:(j + 1) * bm, k * bk:(k + 1) * bk]
                if abs(i - j) < band_blocks:
                    ah = jnp.asarray(a).astype(hi_dtype)
                    bh = jnp.asarray(b).astype(hi_dtype)
                    d = jnp.matmul(ah, bh.T, preferred_element_type=accum_dtype)
                    acc += np.asarray(d, np.float32)
                else:
                    alo = jnp.asarray(a).astype(lo_dtype)
                    blo = jnp.asarray(b).astype(lo_dtype)
                    d = jnp.matmul(alo, blo.T, preferred_element_type=accum_dtype)
                    acc += np.asarray(d.astype(lo_dtype), np.float32)
            out[i * bm:(i + 1) * bm, j * bm:(j + 1) * bm] = acc
    return jnp.asarray(out).astype(hi_dtype)
