"""Pure-jnp oracle for the banded mixed-precision SYRK kernel."""

import numpy as np
import jax.numpy as jnp


def mp_syrk_ref(p, *, band_blocks: int, bm: int = 128, bk: int = 128):
    """Blockwise reference with identical precision routing and k-loop
    rounding order as the kernel."""
    m, kdim = p.shape
    nb = m // bm
    nk = kdim // bk
    out = np.zeros((m, m), dtype=np.float32)
    p = np.asarray(p, np.float32)
    for i in range(nb):
        for j in range(nb):
            acc = np.zeros((bm, bm), np.float32)
            for k in range(nk):
                a = p[i * bm:(i + 1) * bm, k * bk:(k + 1) * bk]
                b = p[j * bm:(j + 1) * bm, k * bk:(k + 1) * bk]
                if abs(i - j) < band_blocks:
                    acc += a @ b.T
                else:
                    a16 = jnp.asarray(a).astype(jnp.bfloat16)
                    b16 = jnp.asarray(b).astype(jnp.bfloat16)
                    d = jnp.matmul(a16, b16.T, preferred_element_type=jnp.float32)
                    acc += np.asarray(d.astype(jnp.bfloat16).astype(jnp.float32))
            out[i * bm:(i + 1) * bm, j * bm:(j + 1) * bm] = acc
    return jnp.asarray(out)
