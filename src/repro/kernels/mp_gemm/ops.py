"""Jitted public wrapper for the banded mixed-precision SYRK."""

from functools import partial

import jax

from .mp_gemm import mp_syrk_pallas


@partial(jax.jit, static_argnames=("band_blocks", "bm", "bk", "interpret"))
def mp_syrk(p, *, band_blocks: int, bm: int = 128, bk: int = 128,
            interpret: bool = True):
    return mp_syrk_pallas(p, band_blocks=band_blocks, bm=bm, bk=bk,
                          interpret=interpret)
