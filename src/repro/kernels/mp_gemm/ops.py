"""Jitted public wrapper for the banded mixed-precision SYRK."""

from functools import partial

import jax
import jax.numpy as jnp

from .mp_gemm import mp_syrk_pallas


@partial(jax.jit, static_argnames=("band_blocks", "bm", "bk", "hi_dtype",
                                   "lo_dtype", "accum_dtype", "interpret"))
def mp_syrk(p, *, band_blocks: int, bm: int = 128, bk: int = 128,
            hi_dtype=jnp.float32, lo_dtype=jnp.bfloat16,
            accum_dtype=jnp.float32, interpret: bool = True):
    return mp_syrk_pallas(p, band_blocks=band_blocks, bm=bm, bk=bk,
                          hi_dtype=hi_dtype, lo_dtype=lo_dtype,
                          accum_dtype=accum_dtype, interpret=interpret)
