"""Pallas TPU kernel: banded mixed-precision SYRK (the paper's sgemm/dgemm).

The trailing update U = P P^T of the tile Cholesky is the FLOP-dominant
phase.  This kernel reproduces Algorithm 1's per-tile precision routing on
the TPU: output blocks within `band_blocks` of the diagonal are computed as
fp32 MXU dots (the paper's dgemm); blocks outside the band are computed as
bf16 x bf16 -> fp32-accumulate MXU dots and rounded through bf16 (the
paper's sgemm + SP storage).  `pl.when` selects exactly one branch per
block, so off-band blocks really do run at bf16 MXU throughput (~6-8x the
fp32 rate on v5e) -- this is where the paper's 1.6x shows up on TPU.

K is looped over via a third grid dimension with fp32 accumulation in the
output block (revisited across k steps: the out index_map ignores k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mp_syrk_kernel(p_i_ref, p_j_ref, out_ref, *, band_blocks: int, nk: int,
                    hi_dtype, lo_dtype, accum_dtype):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    in_band = jnp.abs(i - j) < band_blocks

    @pl.when(in_band)
    def _hi():
        a = p_i_ref[...].astype(hi_dtype)
        b = p_j_ref[...].astype(hi_dtype)
        out_ref[...] += jnp.dot(a, b.T, preferred_element_type=accum_dtype)

    @pl.when(jnp.logical_not(in_band))
    def _lo():
        a = p_i_ref[...].astype(lo_dtype)
        b = p_j_ref[...].astype(lo_dtype)
        acc = jnp.dot(a, b.T, preferred_element_type=accum_dtype)
        # lo storage rounding (the paper's SP tile store)
        out_ref[...] += acc.astype(lo_dtype).astype(out_ref.dtype)


def mp_syrk_pallas(p, *, band_blocks: int, bm: int = 128, bk: int = 128,
                   hi_dtype=jnp.float32, lo_dtype=jnp.bfloat16,
                   accum_dtype=jnp.float32, interpret: bool = True):
    """U = P P^T with banded precision.  p: (m, kdim) fp32 -> (m, m) hi.

    Off-band blocks carry lo-rounded values (per k-step), matching the lo
    storage semantics of the panel engine.  The {hi, lo, accum} routing is
    a PrecisionPolicy projection: pass policy.hi / policy.lo /
    policy.accum_dtype to run the kernel under a non-default pair.
    """
    m, kdim = p.shape
    assert m % bm == 0 and kdim % bk == 0, (m, bm, kdim, bk)
    nk = kdim // bk
    grid = (m // bm, m // bm, nk)
    return pl.pallas_call(
        functools.partial(_mp_syrk_kernel, band_blocks=band_blocks, nk=nk,
                          hi_dtype=hi_dtype, lo_dtype=lo_dtype,
                          accum_dtype=accum_dtype),
        out_shape=jax.ShapeDtypeStruct((m, m), hi_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),
        interpret=interpret,
    )(p, p)
