"""Public API for banded-precision decode attention.

banded_decode_attention(q, near KV bf16, far KV int8) -> attention output.
quantize_kv() produces the far-segment int8 blocks + per-block scales.
GQA is handled by folding kv_heads into the batch dim.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .mp_attention import flash_decode_segment


def quantize_kv(k, v, *, blk: int = 128):
    """Per-(batch, block) symmetric int8 quantization of a KV segment.

    k, v: (B, S, d) float -> int8 (B, S, d), scales (B, S//blk, 2) fp32.
    """
    b, s, d = k.shape
    assert s % blk == 0
    nblk = s // blk
    kb = k.astype(jnp.float32).reshape(b, nblk, blk, d)
    vb = v.astype(jnp.float32).reshape(b, nblk, blk, d)
    k_sc = jnp.max(jnp.abs(kb), axis=(2, 3)) / 127.0 + 1e-12
    v_sc = jnp.max(jnp.abs(vb), axis=(2, 3)) / 127.0 + 1e-12
    kq = jnp.round(kb / k_sc[:, :, None, None]).astype(jnp.int8).reshape(b, s, d)
    vq = jnp.round(vb / v_sc[:, :, None, None]).astype(jnp.int8).reshape(b, s, d)
    scales = jnp.stack([k_sc, v_sc], axis=-1)
    return kq, vq, scales


def merge_partials(parts):
    """Combine per-segment (acc, m, l) with the log-sum-exp merge."""
    accs, ms, ls = zip(*parts)
    m_tot = ms[0]
    for m in ms[1:]:
        m_tot = jnp.maximum(m_tot, m)
    num = jnp.zeros_like(accs[0])
    den = jnp.zeros_like(ls[0])
    for acc, m, l in parts:
        w = jnp.exp(m - m_tot)
        num = num + acc * w
        den = den + l * w
    return num / den


@partial(jax.jit, static_argnames=("blk", "sm_scale", "interpret"))
def banded_decode_attention(q, k_near, v_near, near_len,
                            k_far, v_far, far_scales, far_len, *,
                            blk: int = 128, sm_scale: float = 1.0,
                            interpret: bool = True):
    """Decode attention over a two-precision KV cache.

    q: (B, G, d); near: (B, Sn, d) bf16/f32; far: (B, Sf, d) int8 with
    (B, Sf//blk, 2) scales; *_len: (B,) valid lengths per segment.
    Returns (B, G, d) fp32.
    """
    near = flash_decode_segment(q, k_near, v_near, None, near_len,
                                blk=blk, sm_scale=sm_scale, interpret=interpret)
    far = flash_decode_segment(q, k_far, v_far, far_scales, far_len,
                               blk=blk, sm_scale=sm_scale, interpret=interpret)
    return merge_partials([near, far])
