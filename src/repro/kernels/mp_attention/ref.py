"""Pure-jnp oracle for banded-precision decode attention."""

import jax
import jax.numpy as jnp


def banded_decode_attention_ref(q, k_near, v_near, near_len,
                                k_far, v_far, far_scales, far_len, *,
                                blk: int = 128, sm_scale: float = 1.0):
    """Full-softmax reference with identical quantization semantics."""
    b, g, d = q.shape
    q = q.astype(jnp.float32)

    def dequant(x, scales, col):
        nblk = scales.shape[1]
        xb = x.astype(jnp.float32).reshape(b, nblk, -1, d)
        return (xb * scales[:, :, col][:, :, None, None]).reshape(b, -1, d)

    kf = dequant(k_far, far_scales, 0)
    vf = dequant(v_far, far_scales, 1)
    kn = k_near.astype(jnp.float32)
    vn = v_near.astype(jnp.float32)

    k = jnp.concatenate([kn, kf], axis=1)
    v = jnp.concatenate([vn, vf], axis=1)
    pos_n = jnp.arange(kn.shape[1])[None] < near_len[:, None]
    pos_f = jnp.arange(kf.shape[1])[None] < far_len[:, None]
    valid = jnp.concatenate([pos_n, pos_f], axis=1)          # (B, S)

    scores = jnp.einsum("bgd,bsd->bgs", q, k) * sm_scale
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgs,bsd->bgd", p, v)
