"""Pallas TPU kernel: banded-precision flash decode attention.

The paper's insight -- correlation decays with distance, so numerical
precision can too -- transplanted to the LM serving path (DESIGN.md §9):
during decode, the KV cache splits into

  * a NEAR segment (recent window) stored in bf16, and
  * a FAR segment (distant tokens) quantized to int8 with per-block scales
    (the "single precision off-band tiles"; an int8 block is the KV-cache
    analogue of the paper's SP tile, halving decode HBM traffic -- decode
    is memory-bound, so this converts directly into step-time).

One flash-decode kernel processes one segment: grid (batch*kv_head,
kv_blocks), online-softmax state (m, l, acc) accumulated in the revisited
output blocks.  ops.py runs the kernel once per segment and merges the
partial softmaxes (the standard sequence-parallel attention combine).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_segment_kernel(q_ref, k_ref, v_ref, scale_ref, len_ref,
                          acc_ref, m_ref, l_ref, *,
                          blk: int, sm_scale: float, dequant: bool):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)            # (1, G, d) block
    q = q.reshape(q.shape[-2:])                   # (G, d)
    k = k_ref[...].reshape(k_ref.shape[-2:])      # (blk, d)
    v = v_ref[...].reshape(v_ref.shape[-2:])      # (blk, d)
    if dequant:
        k_sc = scale_ref[0, 0, 0]
        v_sc = scale_ref[0, 0, 1]
        k = k.astype(jnp.float32) * k_sc
        v = v.astype(jnp.float32) * v_sc
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    # mask out positions beyond the segment's valid length
    seg_len = len_ref[0, 0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, k.shape[0]), 1) + s * blk
    valid = pos < seg_len                          # (1, blk)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    scores = jnp.where(valid, scores, NEG_INF)     # (G, blk)

    g = q.shape[0]
    m_prev = m_ref[...].reshape(g, 1)
    l_prev = l_ref[...].reshape(g, 1)
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_ref[...].reshape(q.shape) * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_ref[...] = m_new.reshape(m_ref.shape)
    l_ref[...] = l_new.reshape(l_ref.shape)
    acc_ref[...] = acc_new.reshape(acc_ref.shape)


def flash_decode_segment(q, k, v, scales, seg_len, *, blk: int = 128,
                         sm_scale: float = 1.0, interpret: bool = True):
    """Partial flash attention over one KV segment.

    q: (B, G, d) fp32/bf16 -- B folds batch*kv_heads, G = q heads per kv.
    k, v: (B, S, d) bf16 (near) or int8 (far).
    scales: (B, S//blk, 2) fp32 per-block (k, v) dequant scales, or None.
    seg_len: (B,) int32 valid lengths (for ragged/growing caches).
    Returns un-normalized (acc (B, G, d) f32, m (B, G, 1), l (B, G, 1)).
    """
    b, g, d = q.shape
    s = k.shape[1]
    assert s % blk == 0, (s, blk)
    nblk = s // blk
    dequant = scales is not None
    if scales is None:
        scales = jnp.zeros((b, nblk, 2), jnp.float32)
    seg_len2d = seg_len.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_flash_segment_kernel, blk=blk,
                               sm_scale=sm_scale, dequant=dequant)
    acc, m, l = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, g, 1), jnp.float32),
        ),
        grid=(b, nblk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, s_: (i, 0, 0)),
            pl.BlockSpec((1, blk, d), lambda i, s_: (i, s_, 0)),
            pl.BlockSpec((1, blk, d), lambda i, s_: (i, s_, 0)),
            pl.BlockSpec((1, 1, 2), lambda i, s_: (i, s_, 0)),
            pl.BlockSpec((1, 1), lambda i, s_: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, g, d), lambda i, s_: (i, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda i, s_: (i, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda i, s_: (i, 0, 0)),
        ),
        interpret=interpret,
    )(q, k, v, scales, seg_len2d)
    return acc, m, l
