"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler mitigation hooks, elastic re-mesh.

At 1000+ nodes the dominant failure modes are (a) node loss -> restart
from the latest checkpoint on a (possibly smaller) slice, (b) stragglers ->
bounded step time + re-dispatch, (c) data-stream divergence on resume ->
counter-based pipeline (data/pipeline.py) makes resumption exact.

The loop below implements the restart discipline end-to-end on CPU; the
same structure drives the multi-pod launcher (launch/train.py).  XLA's
static SPMD schedule removes scheduler-induced stragglers by construction
(DESIGN.md §8); node-level stragglers surface as slow steps and trip the
`step_timeout` re-dispatch path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from ..checkpoint import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    step_timeout: float = 600.0       # straggler bound (s)
    max_restarts: int = 3
    keep_last: int = 2


class FaultTolerantLoop:
    def __init__(self, lc: LoopConfig, train_step: Callable, source,
                 init_state, *, shardings=None,
                 failure_injector: Optional[Callable] = None):
        self.lc = lc
        self.train_step = train_step
        self.source = source
        self.init_state = init_state
        self.shardings = shardings
        self.failure_injector = failure_injector
        self.restarts = 0
        self.metrics_log = []

    def _resume_state(self):
        last = ckpt.latest_step(self.lc.ckpt_dir)
        if last is None:
            return self.init_state, 0
        state = ckpt.restore(self.lc.ckpt_dir, last, self.init_state,
                             shardings=self.shardings)
        return state, last

    def run(self):
        """Run to max_steps, surviving injected failures via restart."""
        while True:
            state, start = self._resume_state()
            try:
                state = self._run_from(state, start)
                return state
            except RuntimeError as e:  # injected / real step failure
                self.restarts += 1
                if self.restarts > self.lc.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.lc.max_restarts}") from e
                # fall through: loop resumes from the latest checkpoint

    def _run_from(self, state, start_step: int):
        pending = None
        for step in range(start_step, self.lc.max_steps):
            if self.failure_injector is not None:
                self.failure_injector(step)
            batch = self.source.batch_at(step)
            t0 = time.monotonic()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            if dt > self.lc.step_timeout:
                raise RuntimeError(f"straggler: step {step} took {dt:.1f}s")
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]), "time": dt})
            if (step + 1) % self.lc.ckpt_every == 0:
                if pending is not None:
                    pending.result()  # backpressure: one in flight
                pending = ckpt.save(self.lc.ckpt_dir, step + 1, state)
                self._gc(step + 1)
        if pending is not None:
            pending.result()
        ckpt.save(self.lc.ckpt_dir, self.lc.max_steps, state,
                  async_=False).result()
        return state

    def _gc(self, newest: int):
        import os, shutil
        if not os.path.isdir(self.lc.ckpt_dir):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.lc.ckpt_dir)
                       if d.startswith("step_"))
        for s in steps[:-self.lc.keep_last]:
            shutil.rmtree(os.path.join(self.lc.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)


def make_failure_injector(fail_at_steps):
    """Raise a simulated node failure the FIRST time each step is reached."""
    remaining = set(fail_at_steps)

    def inject(step):
        if step in remaining:
            remaining.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")
    return inject
