from .compression import compress_with_feedback, compression_ratio, init_residual
from .fault_tolerance import FaultTolerantLoop, LoopConfig, make_failure_injector
