"""Gradient compression with error feedback (distributed-optimization trick).

At 1000+ node scale the gradient all-reduce dominates the collective term
for DP-heavy meshes.  Quantizing gradients before the reduce (bf16, or int8
with per-tensor scales) halves/quarters the bytes on the wire; the error-
feedback residual re-injects the rounding error on the next step, which is
what keeps convergence intact (Seide et al. / 1-bit-Adam lineage).

Usage: wrap the grads between `jax.grad` and the optimizer:

  grads_q, residual = compress_with_feedback(grads, residual, mode="int8")

The compressed representation is what crosses the mesh (in SPMD, the
all-reduce runs on the quantized dtype); tests validate convergence parity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_leaf(g, mode):
    if mode == "bf16":
        q = g.astype(jnp.bfloat16)  # repro: disable=no-implicit-downcast -- mode="bf16" wire format
        return q, q.astype(jnp.float32)
    if mode == "int8":
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        q = jnp.round(g / scale).astype(jnp.int8)  # repro: disable=no-implicit-downcast -- mode="int8" wire format
        return (q, scale), q.astype(jnp.float32) * scale
    raise ValueError(mode)


def compress_with_feedback(grads, residual, *, mode: str = "bf16"):
    """Returns (dequantized grads to feed the optimizer, new residual).

    residual: pytree like grads (zeros on the first step)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        target = g.astype(jnp.float32) + r
        _, deq = _quantize_leaf(target, mode)
        return deq, target - deq

    out = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_res


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(mode: str) -> float:
    """Bytes-on-the-wire ratio vs fp32 all-reduce (for the roofline model)."""
    return {"none": 1.0, "bf16": 0.5, "int8": 0.25}[mode]
