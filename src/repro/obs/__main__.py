"""CLI for the telemetry layer.

    python -m repro.obs calibrate [--nb 32 --p 6 --reps 3] [--out PATH]
        Measure per-(kind, tier) tile-kernel wall times by replaying an
        engine task graph with the executor's kernels, and persist the
        table to launch/calibration.json (or --out).  After this,
        `SchedConfig(calibrated=True)` prices simulated schedules with
        measured durations instead of analytic MXU weights.

    python -m repro.obs demo-trace [--out merged-trace.json]
        Run a small factorization through the threaded scheduler with
        telemetry on, merge the host-side spans into the scheduler's
        Chrome trace, validate it, and print the telemetry summary.
        Open the file in chrome://tracing or https://ui.perfetto.dev.
"""

from __future__ import annotations

import argparse
import json
import sys

# NB: `from . import calibrate` would yield the *function* the package
# __init__ re-exports, not the submodule -- import the function directly.
from . import export, recorder
from .calibrate import calibrate as _calibrate


def _cmd_calibrate(args) -> int:
    path = _calibrate(nb=args.nb, p=args.p, reps=args.reps,
                      variant=args.variant, path=args.out)
    payload = json.loads(path.read_text())
    print(f"calibration: wrote {path}")
    width = max(len(k) for k in payload["costs"])
    for key, us in payload["costs"].items():
        print(f"  {key:<{width}}  {us:>10.1f} us")
    meta = payload["meta"]
    print(f"  ({meta['variant']} variant, p={meta['p']}, nb={meta['nb']}, "
          f"{meta['reps']} reps, backend={meta['backend']})")
    return 0


def _cmd_demo_trace(args) -> int:
    from ..core.precision import PrecisionPolicy
    from ..core.tile_cholesky import tile_cholesky
    from ..sched.config import SchedConfig
    from ..sched.runtime import scheduled_tile_cholesky
    from ..sched.trace import validate_trace
    from ..verify.generators import spd_matrix

    policy = PrecisionPolicy.tpu(2)
    a = spd_matrix(0, args.p * args.nb, cond=100.0)
    config = SchedConfig(priority="critical_path", workers=args.workers,
                         backend="real")
    with recorder.recording() as rec:
        with recorder.span("demo.engine_pass"):
            tile_cholesky(a, args.nb, policy)   # eager engine spans
        with recorder.span("demo.scheduled_pass", workers=args.workers):
            _, report = scheduled_tile_cholesky(a, args.nb, policy, config)
        trace = export.write_merged_trace(report, rec, args.out)
        validate_trace(trace)
        print(export.summary_table(rec))
    xs = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    sched_n = sum(1 for e in xs if e["pid"] == 0)
    host_n = sum(1 for e in xs if e["pid"] == export.HOST_PID)
    print(f"demo-trace: wrote + validated {args.out} "
          f"({sched_n} scheduler tasks, {host_n} host spans)")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Telemetry: kernel-time calibration + merged-trace demo")
    sub = parser.add_subparsers(dest="cmd", required=True)

    cal = sub.add_parser("calibrate",
                         help="measure + persist per-(kind, tier) kernel "
                              "times for the scheduler cost model")
    cal.add_argument("--nb", type=int, default=32, help="tile edge")
    cal.add_argument("--p", type=int, default=6, help="tile-grid size")
    cal.add_argument("--reps", type=int, default=3,
                     help="timed replays (median is persisted)")
    cal.add_argument("--variant", default="tile",
                     choices=("tile", "panel", "dst"))
    cal.add_argument("--out", default=None, metavar="PATH",
                     help="write here instead of launch/calibration.json")
    cal.set_defaults(fn=_cmd_calibrate)

    demo = sub.add_parser("demo-trace",
                          help="run a scheduled factorization with telemetry "
                               "on and write a merged Chrome trace")
    demo.add_argument("--out", default="merged-trace.json", metavar="PATH")
    demo.add_argument("--p", type=int, default=6)
    demo.add_argument("--nb", type=int, default=16)
    demo.add_argument("--workers", type=int, default=4)
    demo.set_defaults(fn=_cmd_demo_trace)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
