"""Thread-safe telemetry recorder: counters, gauges, histograms, spans.

This is the repo's analogue of StarPU's per-task profiling hooks
(ExaGeoStat ships the same thing behind `STARPU_PROFILING`): one global
`Recorder` that every instrumented layer -- the tile/panel engines, the
dynamic scheduler, the batch MLE loop, the conformance sweep -- writes
into when telemetry is on, and that costs one global-bool read per call
site when it is off.

Design constraints (DESIGN.md §13):

  * Zero dependencies, stdlib only.  JAX is never imported here; the
    `maybe_span` tracer guard imports it lazily at the call site's first
    *enabled* use.
  * Near-zero cost when disabled: the module-level `span`/`inc`/`observe`
    helpers check one module global and return a shared no-op object.
    Nothing allocates, nothing locks.
  * Instrumentation lives at dispatch boundaries only.  A span timed
    inside jit-traced code would measure trace time once and then never
    run again; `maybe_span(name, *arrays)` therefore degrades to the
    no-op span when any guard array is a JAX tracer.
  * Spans nest: each recorder keeps a per-thread stack so every finished
    span knows its depth (the Chrome-trace bridge lays depths out as
    separate tracks) and unwinds correctly through exceptions.

Everything the recorder holds is a plain value (floats, strings, dicts),
so exporters (`obs.export`) can serialize without touching device arrays.
"""

from __future__ import annotations

import dataclasses
import threading
import time

# Default histogram bucket edges, seconds.  Log-spaced decades from 10 us
# to 100 s: wide enough for one tile op (~100 us eager on CPU) and for a
# full conformance sweep cell (~seconds).  Prometheus "le" convention:
# bucket i counts observations with value <= edges[i]; one overflow
# bucket (+Inf) catches the rest.
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0)


@dataclasses.dataclass
class Histogram:
    """Fixed-bucket histogram (Prometheus semantics: cumulative `le` edges)."""

    edges: tuple[float, ...] = DEFAULT_BUCKETS

    def __post_init__(self):
        if not self.edges or list(self.edges) != sorted(self.edges):
            raise ValueError(f"bucket edges must be sorted, got {self.edges}")
        self.counts = [0] * (len(self.edges) + 1)   # last = +Inf overflow
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        value = float(value)
        lo, hi = 0, len(self.edges)
        while lo < hi:                     # first edge with value <= edge
            mid = (lo + hi) // 2
            if value <= self.edges[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def bucket_rows(self) -> list[tuple[float, int]]:
        """(le_edge, cumulative_count) rows, Prometheus exposition order."""
        rows, cum = [], 0
        for edge, c in zip(self.edges, self.counts):
            cum += c
            rows.append((edge, cum))
        rows.append((float("inf"), self.count))
        return rows

    def as_dict(self) -> dict:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self.count, "total": self.total,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None}


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span: wall-clock interval + context."""
    name: str
    start: float               # time.perf_counter() seconds
    end: float
    thread: int                # threading.get_ident() of the running thread
    depth: int                 # nesting depth on that thread (0 = root)
    status: str                # "ok" | "error"
    attrs: dict

    @property
    def duration(self) -> float:
        return self.end - self.start


class _Span:
    """Context manager that records a SpanRecord into its recorder."""

    __slots__ = ("_rec", "name", "attrs", "_start", "_depth")

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self._rec = rec
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._depth = self._rec._push()
        self._start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        end = time.perf_counter()
        self._rec._pop()
        self._rec._finish(SpanRecord(
            name=self.name, start=self._start, end=end,
            thread=threading.get_ident(), depth=self._depth,
            status="error" if exc_type is not None else "ok",
            attrs=self.attrs))
        return False               # never swallow exceptions


class _NullSpan:
    """Shared no-op span: the entire disabled-mode cost is `with _NULL:`."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class Recorder:
    """Counters + gauges + histograms + spans behind one lock.

    All mutation goes through one `threading.Lock`; the executor's worker
    threads and the host MLE loop can write concurrently.  Span nesting
    depth is tracked per thread in a `threading.local`, outside the lock
    (each thread only touches its own stack).

    The ``# repro: guarded-by=_lock`` annotations are machine-checked by
    `analysis.concurrency.lockguard`: mutating an annotated attribute
    outside a ``with self._lock:`` block (or a ``*_locked`` method, whose
    contract is lock-held-by-caller) is a lint finding.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._tls = threading.local()   # per-thread span stack: lock-free
        self.counters: dict[str, float] = {}      # repro: guarded-by=_lock
        self.gauges: dict[str, float] = {}        # repro: guarded-by=_lock
        self.histograms: dict[str, Histogram] = {}  # repro: guarded-by=_lock
        self.spans: list[SpanRecord] = []         # repro: guarded-by=_lock

    # ---- span plumbing (thread-local, lock-free) -----------------------
    def _push(self) -> int:
        depth = getattr(self._tls, "depth", 0)
        self._tls.depth = depth + 1
        return depth

    def _pop(self) -> None:
        self._tls.depth = getattr(self._tls, "depth", 1) - 1

    def _finish(self, record: SpanRecord) -> None:
        with self._lock:
            self.spans.append(record)
            self._observe_locked(record.name, record.duration)

    # ---- public API ----------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float,
                buckets: tuple[float, ...] | None = None) -> None:
        with self._lock:
            self._observe_locked(name, value, buckets)

    def _observe_locked(self, name, value, buckets=None):
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(buckets or DEFAULT_BUCKETS)
        h.observe(value)

    def snapshot(self) -> dict:
        """Plain-dict copy of everything (for exporters; lock held once)."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.as_dict()
                               for k, h in self.histograms.items()},
                "spans": list(self.spans),
            }

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()
            self.spans.clear()


# ---------------------------------------------------------------------------
# global switch -- the one flag every instrumented call site checks
# ---------------------------------------------------------------------------

_ENABLED = False
_RECORDER = Recorder()


def enabled() -> bool:
    """Is telemetry on?  One global read -- safe to call anywhere, often."""
    return _ENABLED


def enable(recorder: Recorder | None = None) -> Recorder:
    """Turn telemetry on (optionally onto a caller-owned recorder)."""
    global _ENABLED, _RECORDER
    if recorder is not None:
        _RECORDER = recorder
    _ENABLED = True
    return _RECORDER


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def get_recorder() -> Recorder:
    return _RECORDER


class recording:
    """`with obs.recording() as rec:` -- enable onto a fresh recorder and
    restore the previous state on exit (benchmarks, tests, CLI runs)."""

    def __init__(self, recorder: Recorder | None = None):
        self._recorder = recorder or Recorder()

    def __enter__(self) -> Recorder:
        self._prev = (_ENABLED, _RECORDER)
        return enable(self._recorder)

    def __exit__(self, exc_type, exc, tb):
        global _ENABLED, _RECORDER
        _ENABLED, _RECORDER = self._prev
        return False


# ---------------------------------------------------------------------------
# module-level helpers: the instrumented layers call these, not the recorder
# ---------------------------------------------------------------------------

def span(name: str, **attrs):
    """Nestable wall-clock timer; no-op (shared singleton) when disabled."""
    if not _ENABLED:
        return NULL_SPAN
    return _RECORDER.span(name, **attrs)


def _is_tracing(arrays) -> bool:
    import jax   # lazy: only reached when telemetry is enabled

    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def maybe_span(name: str, *guard_arrays, **attrs):
    """`span(...)` that degrades to the no-op inside jit-traced code.

    Pass the function's array arguments as guards: if any is a JAX
    tracer, the caller is being traced (vmap/jit/grad) and a wall-clock
    span would time tracing, not execution -- so record nothing.  Spans
    therefore fire only at dispatch boundaries (eager calls / host loops),
    which is the only place wall time means anything.
    """
    if not _ENABLED:
        return NULL_SPAN
    if guard_arrays and _is_tracing(guard_arrays):
        return NULL_SPAN
    return _RECORDER.span(name, **attrs)


def inc(name: str, n: float = 1) -> None:
    if _ENABLED:
        _RECORDER.inc(name, n)


def gauge(name: str, value: float) -> None:
    if _ENABLED:
        _RECORDER.gauge(name, value)


def observe(name: str, value: float,
            buckets: tuple[float, ...] | None = None) -> None:
    if _ENABLED:
        _RECORDER.observe(name, value, buckets)
