"""Unified telemetry layer: spans, metrics, kernel-time calibration.

Public surface (DESIGN.md §13):

  Switch      enabled() / enable() / disable() / recording() -- one global
              flag; every instrumented call site costs a bool read when off.
  Record      span(name, **attrs) context manager (nestable, exception-
              safe), maybe_span(name, *guard_arrays, **attrs) (no-op under
              jit tracing), inc / gauge / observe, Recorder, Histogram.
  Export      write_jsonl / load_jsonl, prometheus_text, summary_table,
              merged_chrome_trace (engine spans + scheduler tasks in one
              Perfetto view).
  Calibrate   measure_kernel_times / calibrate -- persist measured
              per-(kind, tier) kernel times for the scheduler's cost model
              (`launch.costmodel.task_virtual_cost(..., calibrated=True)`).

CLI: `python -m repro.obs calibrate` and `python -m repro.obs demo-trace`.
"""

from .calibrate import calibrate, cost_key, measure_kernel_times, write_calibration
from .export import (
    events,
    load_jsonl,
    merged_chrome_trace,
    prometheus_text,
    summary_from_events,
    summary_rows,
    summary_table,
    write_jsonl,
    write_merged_trace,
)
from .recorder import (
    DEFAULT_BUCKETS,
    Histogram,
    NULL_SPAN,
    Recorder,
    SpanRecord,
    disable,
    enable,
    enabled,
    gauge,
    get_recorder,
    inc,
    maybe_span,
    observe,
    recording,
    span,
)

__all__ = [
    "DEFAULT_BUCKETS", "Histogram", "NULL_SPAN", "Recorder", "SpanRecord",
    "calibrate", "cost_key", "disable", "enable", "enabled", "events",
    "gauge", "get_recorder", "inc", "load_jsonl", "maybe_span",
    "measure_kernel_times", "merged_chrome_trace", "observe",
    "prometheus_text", "recording", "span", "summary_from_events",
    "summary_rows", "summary_table", "write_calibration", "write_jsonl",
    "write_merged_trace",
]
