"""Exporters for the telemetry recorder (DESIGN.md §13).

Four ways out of one `Recorder`:

  * `write_jsonl` / `load_jsonl`     -- append-friendly JSONL event log:
    one line per finished span, then one line per counter/gauge/histogram
    at flush time.  The log is self-contained: `summary_from_events`
    rebuilds the per-span aggregate table from the file alone (the
    round-trip the tests gate on).
  * `prometheus_text`                -- Prometheus text exposition
    (counters, gauges, cumulative-`le` histogram buckets) for scraping a
    long-running benchmark or service loop.
  * `summary_table`                  -- the human-readable per-run table
    the CLI and `benchmarks/run.py --metrics` print.
  * `merged_chrome_trace`            -- the bridge into the scheduler's
    Chrome trace: host-side spans become complete ("X") events on a
    second process track (pid 1), one tid per (thread, nesting depth) so
    nested spans never overlap on a single track and the merged file
    still passes `sched.trace.validate_trace`.  When the recorder holds
    the `sched.t0` gauge (written by the threaded executor), host spans
    and scheduler tasks share one exact timebase; otherwise both streams
    are aligned to their own earliest event.
"""

from __future__ import annotations

import json
import re

from .recorder import Recorder, SpanRecord

_PROM_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _jsonable(value):
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    return str(value)


def _span_event(s: SpanRecord) -> dict:
    return {
        "type": "span",
        "name": s.name,
        "start": s.start,
        "end": s.end,
        "dur": s.duration,
        "thread": s.thread,
        "depth": s.depth,
        "status": s.status,
        "attrs": {k: _jsonable(v) for k, v in s.attrs.items()},
    }


def events(recorder: Recorder) -> list[dict]:
    """The recorder's contents as a flat list of JSON-serializable events."""
    snap = recorder.snapshot()
    out = [_span_event(s) for s in snap["spans"]]
    for name, value in sorted(snap["counters"].items()):
        out.append({"type": "counter", "name": name, "value": value})
    for name, value in sorted(snap["gauges"].items()):
        out.append({"type": "gauge", "name": name, "value": value})
    for name, h in sorted(snap["histograms"].items()):
        out.append({"type": "histogram", "name": name, **h})
    return out


def write_jsonl(recorder: Recorder, path) -> int:
    """Write the JSONL event log; returns the number of lines written."""
    evs = events(recorder)
    with open(path, "w") as fh:
        for ev in evs:
            fh.write(json.dumps(ev) + "\n")
    return len(evs)


def load_jsonl(path) -> list[dict]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


# ---------------------------------------------------------------------------
# summaries
# ---------------------------------------------------------------------------

def summary_from_events(evs: list[dict]) -> list[dict]:
    """Per-span-name aggregate rows from a (possibly reloaded) event list."""
    agg: dict[str, dict] = {}
    for ev in evs:
        if ev.get("type") != "span":
            continue
        row = agg.setdefault(ev["name"], {
            "name": ev["name"], "count": 0, "total": 0.0, "max": 0.0,
            "errors": 0})
        row["count"] += 1
        row["total"] += ev["dur"]
        row["max"] = max(row["max"], ev["dur"])
        row["errors"] += ev["status"] == "error"
    for row in agg.values():
        row["mean"] = row["total"] / row["count"]
    return sorted(agg.values(), key=lambda r: -r["total"])


def summary_rows(recorder: Recorder) -> list[dict]:
    return summary_from_events(events(recorder))


def summary_table(recorder: Recorder) -> str:
    """Human-readable per-run summary: spans, counters, gauges, histograms."""
    snap = recorder.snapshot()
    lines: list[str] = []
    span_rows = summary_from_events([_span_event(s) for s in snap["spans"]])
    if span_rows:
        lines.append(f"{'span':<36} {'count':>7} {'total_s':>10} "
                     f"{'mean_s':>10} {'max_s':>10} {'err':>4}")
        for r in span_rows:
            lines.append(f"{r['name']:<36} {r['count']:>7} {r['total']:>10.4f} "
                         f"{r['mean']:>10.5f} {r['max']:>10.5f} "
                         f"{r['errors']:>4}")
    if snap["counters"]:
        lines.append("counters:")
        for name, value in sorted(snap["counters"].items()):
            lines.append(f"  {name} = {value:g}")
    if snap["gauges"]:
        lines.append("gauges:")
        for name, value in sorted(snap["gauges"].items()):
            lines.append(f"  {name} = {value:g}")
    hist_only = {k: h for k, h in snap["histograms"].items()
                 if k not in {r["name"] for r in span_rows}}
    if hist_only:
        lines.append("histograms:")
        for name, h in sorted(hist_only.items()):
            lines.append(
                f"  {name}: n={h['count']} mean={h['total'] / max(h['count'], 1):.5f}"
                f" min={h['min']} max={h['max']}")
    return "\n".join(lines) if lines else "(recorder is empty)"


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _prom_name(name: str) -> str:
    return "repro_" + _PROM_NAME_RE.sub("_", name)


def prometheus_text(recorder: Recorder) -> str:
    """Prometheus text-format exposition of counters, gauges, histograms."""
    snap = recorder.snapshot()
    lines: list[str] = []
    for name, value in sorted(snap["counters"].items()):
        pn = _prom_name(name)
        lines += [f"# TYPE {pn} counter", f"{pn} {value:g}"]
    for name, value in sorted(snap["gauges"].items()):
        pn = _prom_name(name)
        lines += [f"# TYPE {pn} gauge", f"{pn} {value:g}"]
    for name, h in sorted(snap["histograms"].items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} histogram")
        cum = 0
        for edge, count in zip(h["edges"], h["counts"]):
            cum += count
            lines.append(f'{pn}_bucket{{le="{edge:g}"}} {cum}')
        lines.append(f'{pn}_bucket{{le="+Inf"}} {h["count"]}')
        lines.append(f"{pn}_sum {h['total']:g}")
        lines.append(f"{pn}_count {h['count']}")
    return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Chrome trace bridge
# ---------------------------------------------------------------------------

HOST_PID = 1   # scheduler tasks render under pid 0 (sched.trace), spans here


def merged_chrome_trace(report, recorder: Recorder) -> dict:
    """Scheduler tasks + host-side spans in ONE Chrome/Perfetto trace.

    `report` is a `sched.runtime.SchedReport` (real backend: timestamps in
    microseconds since its own t0).  Host spans land on pid 1, one tid per
    (thread, depth): sibling spans on a thread are sequential and parents
    sit on the track above their children, so no track ever has
    overlapping events and `validate_trace` accepts the merged file.
    """
    from ..sched.trace import chrome_trace

    trace = chrome_trace(report)
    snap = recorder.snapshot()
    spans: list[SpanRecord] = snap["spans"]
    if not spans:
        return trace

    t0 = snap["gauges"].get("sched.t0")   # executor start, perf_counter s
    base = min(s.start for s in spans)
    if t0 is not None:
        base = min(base, t0)
        shift = (t0 - base) * 1e6
        if shift:
            for ev in trace["traceEvents"]:
                if ev.get("ph") == "X":
                    ev["ts"] += shift

    events_out = trace["traceEvents"]
    events_out.append({"name": "process_name", "ph": "M", "pid": HOST_PID,
                       "tid": 0, "args": {"name": "repro.obs host spans"}})
    threads = {th: i for i, th in
               enumerate(sorted({s.thread for s in spans}))}
    tracks: dict[tuple[int, int], int] = {}
    for s in sorted(spans, key=lambda s: (threads[s.thread], s.depth, s.start)):
        key = (s.thread, s.depth)
        tid = tracks.get(key)
        if tid is None:
            tid = tracks[key] = len(tracks)
            events_out.append({
                "name": "thread_name", "ph": "M", "pid": HOST_PID,
                "tid": tid,
                "args": {"name": f"host t{threads[s.thread]} depth{s.depth}"},
            })
        events_out.append({
            "name": s.name,
            "cat": "host",
            "ph": "X",
            "ts": (s.start - base) * 1e6,
            "dur": s.duration * 1e6,
            "pid": HOST_PID,
            "tid": tid,
            "args": {"status": s.status, "depth": s.depth,
                     **{k: _jsonable(v) for k, v in s.attrs.items()}},
        })
    trace["otherData"]["host_spans"] = len(spans)
    return trace


def write_merged_trace(report, recorder: Recorder, path) -> dict:
    trace = merged_chrome_trace(report, recorder)
    with open(path, "w") as fh:
        json.dump(trace, fh, indent=1)
    return trace
