"""Kernel-time calibration: measure per-(kind, tier) tile-op wall times.

The simulated scheduler backend prices every task with
`launch.costmodel.task_virtual_cost` -- analytic MXU throughput weights
(fp32 ~6x bf16, fp8 ~0.5x) that describe a TPU v5e, not whatever backend
this container actually runs.  ROADMAP asks for the StarPU move: measure
the per-kind kernel times once, persist them, and let the simulator
consume measured durations instead of analytic ones.

Measurement strategy: replay one engine task graph *in order* with the
real executor's own kernels (`sched.kernels.KernelSet` -- exactly the
math `execute()` runs per task), timing each task around a
`block_until_ready()`.  The operands are therefore real factorization
intermediates at their real dtypes, not synthetic tiles, and every (kind,
tier) pair the DAG can emit shows up with its true operand mix.  One
warmup replay compiles every tile-op shape; `reps` timed replays follow;
the table stores the per-pair median in microseconds.

The default cell (tile variant, mixed fp32/bf16 policy, p=6) emits every
execution pair the three engines use: POTRF/hi, TRSM/hi, TRSM/lo,
SYRK/hi, GEMM/hi, GEMM/lo, and CONVERT.  (lo2 is a *storage* tier only --
fp8 tiles are converted to lo before any compute task touches them, so
there is nothing to measure at lo2; `task_virtual_cost` keeps the
analytic weight for any key a table is missing.)

The persisted table lives at `launch/calibration.json`, next to the cost
model that consumes it (`task_virtual_cost(..., calibrated=True)`).
"""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

from . import recorder as obs


def cost_key(task) -> str:
    """Calibration-table key for one `repro.analysis.dag.Task`."""
    return "CONVERT" if task.kind == "CONVERT" else f"{task.kind}/{task.tier}"


def _replay_timed(graph, kernels, samples: dict[str, list[float]] | None):
    """In-order replay of `graph`, timing each task; mirrors `execute()`'s
    operand fetch so every kernel sees the arrays the executor would."""
    values: list = [None] * graph.n
    for idx, task in enumerate(graph.tasks):
        reads = task.reads if task.kind != "CONVERT" else (task.target,)
        ops = [values[prod] if prod >= 0 else kernels.initial(r)
               for r, prod in zip(reads, graph.deps[idx])]
        t0 = time.perf_counter()
        out = kernels.run(task, ops)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        values[idx] = out
        if samples is not None:
            samples.setdefault(cost_key(task), []).append(dt * 1e6)


def measure_kernel_times(*, nb: int = 32, p: int = 6, reps: int = 3,
                         variant: str = "tile", policy=None,
                         seed: int = 0) -> tuple[dict[str, float], dict]:
    """Measure per-(kind, tier) tile-op times; returns (costs_us, meta).

    costs_us maps "KIND/tier" (CONVERT: flat "CONVERT") to the median
    measured microseconds across `reps` in-order replays of the cell's
    task graph (one unmeasured warmup replay compiles everything first).
    """
    import jax

    from ..core.precision import PrecisionPolicy
    from ..sched.kernels import make_kernels
    from ..sched.runtime import build_graph
    from ..verify.generators import spd_matrix

    policy = policy or PrecisionPolicy.tpu(2)
    n = p * nb
    a = spd_matrix(seed, n, cond=100.0)
    graph = build_graph(variant, p, policy)
    kernels = make_kernels(variant, a, nb, policy)

    with obs.span("obs.calibrate", variant=variant, p=p, nb=nb, reps=reps):
        _replay_timed(graph, kernels, None)          # warmup: compile shapes
        samples: dict[str, list[float]] = {}
        for _ in range(reps):
            _replay_timed(graph, kernels, samples)

    costs = {k: statistics.median(v) for k, v in sorted(samples.items())}
    meta = {
        "units": "microseconds",
        "variant": variant,
        "policy_mode": policy.mode,
        "p": p,
        "nb": nb,
        "reps": reps,
        "backend": jax.default_backend(),
        "n_samples": {k: len(v) for k, v in sorted(samples.items())},
    }
    return costs, meta


def write_calibration(costs: dict[str, float], meta: dict,
                      path=None) -> Path:
    """Persist the measured cost table where the cost model reads it."""
    from ..launch.costmodel import CALIBRATION_PATH, set_calibration

    path = Path(path) if path is not None else CALIBRATION_PATH
    payload = {"meta": meta, "costs": {k: round(v, 3)
                                       for k, v in costs.items()}}
    path.write_text(json.dumps(payload, indent=2) + "\n")
    if path == CALIBRATION_PATH:
        set_calibration(None)    # drop the cache so the new table is read
    return path


def calibrate(*, nb: int = 32, p: int = 6, reps: int = 3,
              variant: str = "tile", policy=None, path=None) -> Path:
    """Measure + persist in one call (the `python -m repro.obs calibrate`
    entry point).  Returns the path written."""
    costs, meta = measure_kernel_times(nb=nb, p=p, reps=reps,
                                       variant=variant, policy=policy)
    return write_calibration(costs, meta, path)
