"""Precision-flow linter: AST rules that enforce the paper's dtype discipline.

The mixed-precision claim (fp64 band / fp32-bf16 off-band "without any
deterioration of numerical accuracy") rests on every cast flowing from a
`PrecisionPolicy`, never from an ad-hoc literal.  This module makes that a
machine-checked invariant over `src/repro/`:

  no-implicit-downcast
      In the policy-governed numerics packages (`core/`, `covariance/`)
      every `.astype(...)` argument must be an expression (a policy field,
      a dtype variable, `x.dtype`), never a literal `jnp.<dtype>` /
      `"dtype"` constant.  Elsewhere only *narrowing* literals (bf16,
      fp16, fp8, int8, int4) are flagged -- widening to fp32 is the
      documented MXU-accumulate idiom and stays legal.

  accum-dtype
      A matmul-family call (`jnp.matmul`/`dot`/`einsum`/`tensordot`,
      `lax.dot_general`) whose operand was cast to a lo tier (literal
      narrow dtype, `*.lo`/`*.lo2`, or a local bound to one) must pass
      `preferred_element_type=...` explicitly; and that accumulator must
      not itself be a narrow literal.  This is the paper's "SP compute,
      wide accumulate" contract (`lo_matmul` is the blessed helper).

  x64-guard
      `jnp.float64` may only appear in modules that visibly deal with x64
      (source mentions `enable_x64`, or carries a `# repro: x64-module`
      marker).  Everywhere else fp64 silently truncates to fp32 under
      default JAX config -- the worst kind of precision bug, invisible
      until the statistics drift.

  pallas-blockspec-contract
      Structural conformance inside `kernels/`: each kernel package's
      `ops.py` public entry points must have a matching `<name>_ref` in
      `ref.py` with identical positional parameters and a ref keyword set
      that is a subset of the op's; every `pl.pallas_call` must have
      index_map arity == grid rank, BlockSpec block-shape rank ==
      index-map output rank, and len(out_specs) == len(out_shape).
      (Out-dtype equality is enforced dynamically by the verify/
      conformance sweep; the static layer covers the shape plumbing.)

  obs-span-context
      Every `span(...)`/`maybe_span(...)` telemetry call must be
      context-managed (`with obs.span(...):` or handed to
      `enter_context(...)`).  A bare call creates a timer that is never
      closed, so the span silently vanishes from every exporter -- the
      observability analogue of an unclosed file handle.  `repro/obs/`
      itself (which defines and returns span objects) is exempt.

Suppression: per-line `# repro: disable=<rule>[,<rule>] -- reason` pragmas
(any line of a multi-line statement), or entries in the committed
`baseline.json` (see baseline.py) for grandfathered findings.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable

RULES = (
    "no-implicit-downcast",
    "accum-dtype",
    "x64-guard",
    "pallas-blockspec-contract",
    "obs-span-context",
)

# Packages where ANY literal-dtype astype is a violation (dtypes must flow
# from a PrecisionPolicy or a dtype-valued variable/parameter).
STRICT_PACKAGES = ("core", "covariance")

# Narrowing storage dtypes: flagged as literals everywhere.
NARROW_DTYPES = frozenset({
    "bfloat16", "float16", "float8_e4m3fn", "float8_e5m2", "float8_e4m3",
    "float8_e5m2fnuz", "float8_e4m3fnuz", "int8", "int4", "uint8", "uint4",
})
# Additional literals banned in STRICT_PACKAGES (all float literals).
FLOAT_DTYPES = NARROW_DTYPES | {"float32", "float64"}

MATMUL_FUNCS = frozenset({"matmul", "dot", "einsum", "tensordot", "dot_general"})

# Telemetry span constructors (repro.obs): must be context-managed.
SPAN_FUNCS = frozenset({"span", "maybe_span"})

# Attribute / name spellings that mark a cast target as "lo tier".
LO_TIER_NAMES = frozenset({"lo", "lo2", "solve_dtype"})

_PRAGMA_RE = re.compile(
    r"#\s*repro:\s*disable=([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)")
_X64_MODULE_RE = re.compile(r"enable_x64|#\s*repro:\s*x64-module")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative posix path
    line: int
    message: str
    code: str          # stripped source line (baseline match key)

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# pragma handling
# ---------------------------------------------------------------------------

def pragma_lines(source: str) -> dict[int, frozenset[str]]:
    """line number (1-based) -> set of rule names disabled on that line."""
    out: dict[int, frozenset[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            rules = frozenset(r.strip() for r in m.group(1).split(",") if r.strip())
            out[i] = rules
    return out


def _suppressed(pragmas: dict[int, frozenset[str]], node: ast.AST, rule: str) -> bool:
    lo = getattr(node, "lineno", None)
    hi = getattr(node, "end_lineno", lo)
    if lo is None:
        return False
    return any(rule in pragmas.get(ln, ()) for ln in range(lo, (hi or lo) + 1))


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------

def _dtype_literal_name(node: ast.AST) -> str | None:
    """Return the dtype name if `node` is a literal dtype expression."""
    if isinstance(node, ast.Attribute):
        # jnp.bfloat16 / np.float32 / jax.numpy.float16
        base = node.value
        base_ok = (isinstance(base, ast.Name) and base.id in ("jnp", "np", "numpy")) or (
            isinstance(base, ast.Attribute) and base.attr == "numpy")
        if base_ok and node.attr in FLOAT_DTYPES:
            return node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        if node.value in FLOAT_DTYPES:
            return node.value
    return None


def _is_lo_tier_expr(node: ast.AST, lo_vars: set[str]) -> bool:
    """True if the expression names a lo-tier dtype (policy.lo, `lo`, narrow
    literal, or a local variable bound to one)."""
    name = _dtype_literal_name(node)
    if name is not None and name in NARROW_DTYPES:
        return True
    if isinstance(node, ast.Attribute) and node.attr in LO_TIER_NAMES:
        return True
    if isinstance(node, ast.Name) and (node.id in LO_TIER_NAMES or node.id in lo_vars):
        return True
    return False


def _contains_lo_cast(node: ast.AST, lo_vars: set[str], lo_arrays: set[str]) -> bool:
    """Expression contains `.astype(<lo>)` or a name bound to a lo-cast value."""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "astype" and sub.args
                and _is_lo_tier_expr(sub.args[0], lo_vars)):
            return True
        if isinstance(sub, ast.Name) and sub.id in lo_arrays:
            return True
    return False


def _func_attr_name(func: ast.AST) -> str | None:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _lambda_arity(node: ast.Lambda) -> int:
    a = node.args
    return len(a.posonlyargs) + len(a.args)


def _static_tuple_len(node: ast.AST) -> int | None:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


# ---------------------------------------------------------------------------
# per-module rule passes
# ---------------------------------------------------------------------------

def _check_downcasts(tree: ast.AST, relpath: str, source_lines: list[str],
                     pragmas, strict: bool) -> list[Finding]:
    banned = FLOAT_DTYPES if strict else NARROW_DTYPES
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype" and node.args):
            continue
        name = _dtype_literal_name(node.args[0])
        if name is None or name not in banned:
            continue
        rule = "no-implicit-downcast"
        if _suppressed(pragmas, node, rule):
            continue
        where = ("policy-scoped module: dtype must flow from a PrecisionPolicy "
                 "field or dtype variable" if strict
                 else "narrowing cast must flow from a policy/tier variable")
        findings.append(Finding(
            rule, relpath, node.lineno,
            f"literal dtype astype({name}) -- {where}",
            source_lines[node.lineno - 1].strip()))
    return findings


def _check_accum(tree: ast.AST, relpath: str, source_lines: list[str],
                 pragmas) -> list[Finding]:
    findings = []
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        # taint-track simple local assignments: dtype vars bound to lo tiers
        # and array vars bound to lo-cast expressions
        lo_vars: set[str] = set()
        lo_arrays: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                tgt = node.targets[0].id
                if _is_lo_tier_expr(node.value, lo_vars):
                    lo_vars.add(tgt)
                elif _contains_lo_cast(node.value, lo_vars, lo_arrays):
                    lo_arrays.add(tgt)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            fname = _func_attr_name(node.func)
            if fname not in MATMUL_FUNCS:
                continue
            kw = {k.arg: k.value for k in node.keywords if k.arg}
            pet = kw.get("preferred_element_type")
            if pet is not None:
                pet_name = _dtype_literal_name(pet)
                if pet_name in NARROW_DTYPES \
                        and not _suppressed(pragmas, node, "accum-dtype"):
                    findings.append(Finding(
                        "accum-dtype", relpath, node.lineno,
                        f"narrow literal accumulator preferred_element_type="
                        f"{pet_name}; use policy.accum_dtype",
                        source_lines[node.lineno - 1].strip()))
                continue
            if any(_contains_lo_cast(a, lo_vars, lo_arrays) for a in node.args) \
                    and not _suppressed(pragmas, node, "accum-dtype"):
                findings.append(Finding(
                    "accum-dtype", relpath, node.lineno,
                    f"lo-precision operand feeds {fname} without an explicit "
                    "preferred_element_type (policy.accum_dtype)",
                    source_lines[node.lineno - 1].strip()))
    return findings


def _check_x64(tree: ast.AST, relpath: str, source: str,
               source_lines: list[str], pragmas) -> list[Finding]:
    if _X64_MODULE_RE.search(source):
        return []
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            base = node.value
            if isinstance(base, ast.Name) and base.id == "jnp" or (
                    isinstance(base, ast.Attribute) and base.attr == "numpy"
                    and isinstance(base.value, ast.Name) and base.value.id == "jax"):
                if _suppressed(pragmas, node, "x64-guard"):
                    continue
                findings.append(Finding(
                    "x64-guard", relpath, node.lineno,
                    "jnp.float64 outside an x64-enabled module (silently "
                    "truncates to fp32 under default JAX config)",
                    source_lines[node.lineno - 1].strip()))
    return findings


def _check_pallas_calls(tree: ast.AST, relpath: str, source_lines: list[str],
                        pragmas) -> list[Finding]:
    """Structural checks on every pl.pallas_call in a kernel module."""
    findings = []

    def flag(node, msg):
        if not _suppressed(pragmas, node, "pallas-blockspec-contract"):
            findings.append(Finding(
                "pallas-blockspec-contract", relpath, node.lineno, msg,
                source_lines[node.lineno - 1].strip()))

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _func_attr_name(node.func) == "pallas_call"):
            continue
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        grid = kw.get("grid")
        grid_rank = _static_tuple_len(grid) if grid is not None else 0
        specs: list[ast.Call] = []
        for key in ("in_specs", "out_specs"):
            v = kw.get(key)
            if v is None:
                continue
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Call) and _func_attr_name(e.func) == "BlockSpec":
                    specs.append(e)
        for spec in specs:
            if len(spec.args) < 2 or not isinstance(spec.args[1], ast.Lambda):
                continue
            lam = spec.args[1]
            arity = _lambda_arity(lam)
            if grid_rank is not None and arity != grid_rank:
                flag(spec, f"BlockSpec index_map takes {arity} args but the "
                           f"grid has rank {grid_rank}")
            blk_rank = _static_tuple_len(spec.args[0])
            body = lam.body
            out_rank = _static_tuple_len(body)
            if out_rank is None and not isinstance(body, ast.Tuple):
                out_rank = 1  # scalar index -> rank-1 block
            if blk_rank is not None and out_rank is not None and blk_rank != out_rank:
                flag(spec, f"BlockSpec block shape has rank {blk_rank} but its "
                           f"index_map yields rank {out_rank}")
        out_shape = kw.get("out_shape")
        out_specs = kw.get("out_specs")
        n_shapes = _static_tuple_len(out_shape) if out_shape is not None else None
        n_specs = _static_tuple_len(out_specs) if out_specs is not None else None
        if n_shapes is not None and n_specs is not None and n_shapes != n_specs:
            flag(node, f"out_shape declares {n_shapes} outputs but out_specs "
                       f"declares {n_specs}")
    return findings


def _check_span_context(tree: ast.AST, relpath: str, source_lines: list[str],
                        pragmas) -> list[Finding]:
    """Flag span()/maybe_span() calls not used as `with` context expressions
    (or fed to ExitStack.enter_context)."""
    allowed: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                ce = item.context_expr
                if isinstance(ce, ast.Call) \
                        and _func_attr_name(ce.func) in SPAN_FUNCS:
                    allowed.add(id(ce))
        elif isinstance(node, ast.Call) \
                and _func_attr_name(node.func) == "enter_context":
            for a in node.args:
                if isinstance(a, ast.Call) \
                        and _func_attr_name(a.func) in SPAN_FUNCS:
                    allowed.add(id(a))
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _func_attr_name(node.func) in SPAN_FUNCS):
            continue
        if id(node) in allowed \
                or _suppressed(pragmas, node, "obs-span-context"):
            continue
        findings.append(Finding(
            "obs-span-context", relpath, node.lineno,
            "span()/maybe_span() must be context-managed (`with "
            "obs.span(...):` or enter_context(...)) -- a bare call opens a "
            "timer that is never closed",
            source_lines[node.lineno - 1].strip()))
    return findings


def _public_functions(tree: ast.AST) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")}  # type: ignore[union-attr]


def _param_names(fn: ast.FunctionDef) -> tuple[list[str], set[str]]:
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    kwonly = {a.arg for a in fn.args.kwonlyargs}
    return pos, kwonly


def check_kernel_package(pkg_dir: Path, root: Path) -> list[Finding]:
    """ops.py <-> ref.py signature conformance for one kernel package."""
    ops_path, ref_path = pkg_dir / "ops.py", pkg_dir / "ref.py"
    findings = []
    rel_ops = ops_path.relative_to(root.parent).as_posix()
    if not ops_path.exists() or not ref_path.exists():
        missing = "ref.py" if ops_path.exists() else "ops.py"
        return [Finding("pallas-blockspec-contract",
                        pkg_dir.relative_to(root.parent).as_posix(), 1,
                        f"kernel package missing {missing} (every kernel ships "
                        "a jitted wrapper AND a pure-jnp oracle)", "")]
    ops_src = ops_path.read_text()
    ref_src = ref_path.read_text()
    ops_fns = _public_functions(ast.parse(ops_src))
    ref_fns = _public_functions(ast.parse(ref_src))
    ops_pragmas = pragma_lines(ops_src)
    ops_lines = ops_src.splitlines()
    matched = 0
    for name, fn in ops_fns.items():
        ref = ref_fns.get(name + "_ref")
        if ref is None:
            continue
        matched += 1
        op_pos, op_kw = _param_names(fn)
        ref_pos, ref_kw = _param_names(ref)
        if _suppressed(ops_pragmas, fn, "pallas-blockspec-contract"):
            continue
        if op_pos != ref_pos:
            findings.append(Finding(
                "pallas-blockspec-contract", rel_ops, fn.lineno,
                f"{name}: positional params {op_pos} != {name}_ref's {ref_pos}",
                ops_lines[fn.lineno - 1].strip()))
        extra = ref_kw - op_kw
        if extra:
            findings.append(Finding(
                "pallas-blockspec-contract", rel_ops, fn.lineno,
                f"{name}: ref requires keywords {sorted(extra)} the op "
                "wrapper does not accept",
                ops_lines[fn.lineno - 1].strip()))
    if not matched:
        findings.append(Finding(
            "pallas-blockspec-contract", rel_ops, 1,
            "no ops.py public function has a matching <name>_ref oracle in "
            "ref.py", ""))
    return findings


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def lint_source(source: str, relpath: str) -> list[Finding]:
    """Lint one module's source text.  relpath is repo-relative posix."""
    tree = ast.parse(source)
    lines = source.splitlines()
    pragmas = pragma_lines(source)
    parts = Path(relpath).parts
    pkg = parts[1] if len(parts) > 1 and parts[0] == "repro" else (
        parts[0] if parts else "")
    strict = pkg in STRICT_PACKAGES
    findings = []
    findings += _check_downcasts(tree, relpath, lines, pragmas, strict)
    findings += _check_accum(tree, relpath, lines, pragmas)
    findings += _check_x64(tree, relpath, source, lines, pragmas)
    if pkg == "kernels":
        findings += _check_pallas_calls(tree, relpath, lines, pragmas)
    if pkg != "obs":   # obs defines/returns span objects; everyone else
        findings += _check_span_context(tree, relpath, lines, pragmas)
    return findings


def lint_tree(root: Path) -> list[Finding]:
    """Lint every module under `root` (the src/repro directory)."""
    root = Path(root)
    findings: list[Finding] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root.parent).as_posix()
        if rel.startswith("repro/analysis/"):
            continue
        findings.extend(lint_source(path.read_text(), rel))
    kernels = root / "kernels"
    if kernels.is_dir():
        for pkg in sorted(p for p in kernels.iterdir() if p.is_dir()):
            if pkg.name.startswith("__"):
                continue
            findings.extend(check_kernel_package(pkg, root))
    return findings
