"""Static analysis for the mixed-precision tile Cholesky (CI gate).

Layer 1 (`lint`): AST precision-flow linter -- dtype discipline as named,
suppressable rules.  Layer 2 (`dag`): symbolic tile-DAG extraction with
RAW/WAR/WAW hazard and precision-edge checking plus per-tier FLOP /
critical-path reports.  `python -m repro.analysis --check` is the blocking
CI entry point; see DESIGN.md "Static analysis".
"""

from .dag import (  # noqa: F401
    DagReport,
    HazardError,
    Task,
    analyze,
    build_dag,
    check_dag,
    dst_dag,
    flop_report,
    generations,
    panel_dag,
    storage_tier,
    successor_map,
    task_dependencies,
    tile_dag,
)
from .lint import Finding, lint_source, lint_tree  # noqa: F401
