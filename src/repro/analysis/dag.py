"""Tile-DAG hazard checker: the StarPU-dependency-tracker guarantee, statically.

ExaGeoStat gets task ordering for free from StarPU's runtime dependency
tracker; our JAX port unrolls the loops at trace time, so a reordering bug
in `tile_cholesky.py` / `panel_cholesky.py` would silently factor with
stale tiles.  This module rebuilds each variant's task graph *symbolically*
(POTRF / TRSM / SYRK / GEMM / CONVERT over tile indices -- no numerics
executed) by transliterating the engines' loop nests, then proves three
properties of the emitted sequential order:

  1. hazard freedom -- every tile obeys the Cholesky dataflow protocol
     (updates for k in increasing order with no gap, factor op exactly
     once at step j, strictly read-only afterwards).  Any RAW (read of a
     not-yet-produced panel/update), WAW (duplicate or out-of-order
     write), or WAR (write into a tile already consumed as factored
     output) is reported with the offending task;

  2. precision-edge consistency -- a task never consumes a tile stored in
     a different tier without an explicit CONVERT (the paper's `dlag2s`
     demote / `sconv2d` promote) of the *current* version; conversions of
     stale versions do not count;

  3. a cost report -- per-tier FLOP totals, conversion traffic, and the
     critical path (longest RAW/WAW chain), consumed by
     launch/costmodel.py and the perf suites' predicted-vs-achieved
     FLOP-mix columns.

The generators mirror the engines the way `ref.py` oracles mirror Pallas
kernels: a trusted transliteration, kept honest by fixture tests that
corrupt a generator (dropped promote, reordered update, duplicate TRSM)
and assert the checker catches it.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from ..core.precision import PrecisionPolicy

HI, LO, LO2 = "hi", "lo", "lo2"
_TIER_RANK = {LO2: 0, LO: 1, HI: 2}

# FLOPs per tile op, in units of nb^3 (nb = tile edge).  POTRF is nb^3/3,
# TRSM nb^3, SYRK nb^3 (symmetric rank-nb update), GEMM 2 nb^3.
_FLOP_UNITS = {"POTRF": 1.0 / 3.0, "TRSM": 1.0, "SYRK": 1.0, "GEMM": 2.0}


@dataclasses.dataclass(frozen=True)
class Task:
    kind: str                      # POTRF | TRSM | SYRK | GEMM | CONVERT
    k: int                         # panel step the task belongs to
    target: tuple[int, int]        # tile written (CONVERT: tile copied)
    reads: tuple[tuple[int, int], ...] = ()
    tier: str = HI                 # execution tier (CONVERT: dst tier)
    src_tier: str | None = None    # CONVERT only: tier of the source value

    def __str__(self):
        rd = ",".join(f"({i},{j})" for i, j in self.reads)
        extra = f" {self.src_tier}->{self.tier}" if self.kind == "CONVERT" \
            else f" [{self.tier}]"
        return f"{self.kind}{self.target}@k={self.k}{extra}" + (
            f" reads {rd}" if rd else "")


class HazardError(AssertionError):
    """A RAW/WAR/WAW or precision-consistency violation in a task stream."""


# ---------------------------------------------------------------------------
# storage-tier maps (mirror PrecisionPolicy.tile_dtype / the panel split)
# ---------------------------------------------------------------------------

def storage_tier(policy: PrecisionPolicy, i: int, j: int, *,
                 variant: str = "tile") -> str | None:
    """Tier the engine stores tile (i, j) in.  None = dropped (DST)."""
    d = abs(i - j)
    if variant == "dst":
        # independent super-blocks of diag_thick tiles: tiles whose row and
        # column fall in the same block are hi, everything else is dropped
        return HI if i // policy.diag_thick == j // policy.diag_thick else None
    if policy.mode == "full" or d < policy.diag_thick:
        return HI
    if variant == "panel":
        # the banded engine's off storage is single-tier policy.lo even for
        # three_tier (build_banded_covariance line "lo = policy.lo")
        return LO
    if policy.mode == "three_tier" and d >= policy.diag_thick2:
        return LO2
    return LO


# ---------------------------------------------------------------------------
# generators: transliterations of the three engines' loop nests
# ---------------------------------------------------------------------------

def tile_dag(p: int, policy: PrecisionPolicy) -> list[Task]:
    """Task stream of core/tile_cholesky.py's unrolled Algorithm 1."""
    if policy.mode == "dst":
        raise ValueError("use dst_dag for the DST baseline")
    tasks: list[Task] = []
    emit = tasks.append
    tier = lambda i, j: storage_tier(policy, i, j, variant="tile")

    for k in range(p):
        emit(Task("POTRF", k, (k, k), reads=((k, k),), tier=HI))
        if any(tier(i, k) != HI for i in range(k + 1, p)):
            # line 9 dlag2s: lo tmp copy of the factored diagonal tile
            emit(Task("CONVERT", k, (k, k), tier=LO, src_tier=HI))

        for i in range(k + 1, p):                     # panel TRSMs
            t_ik = tier(i, k)
            if t_ik == HI:                            # line 12 dtrsm
                emit(Task("TRSM", k, (i, k), reads=((k, k), (i, k)), tier=HI))
            else:                                     # line 14 strsm
                if t_ik == LO2:   # store[(i,k)].astype(lo) promotes far tiles
                    emit(Task("CONVERT", k, (i, k), tier=LO, src_tier=LO2))
                emit(Task("TRSM", k, (i, k), reads=((k, k), (i, k)), tier=LO))

        for j in range(k + 1, p):                     # trailing update
            if tier(j, k) != HI:                      # line 15 sconv2d
                emit(Task("CONVERT", k, (j, k), tier=HI, src_tier=tier(j, k)))
            emit(Task("SYRK", k, (j, j), reads=((j, k), (j, j)), tier=HI))
            for i in range(j + 1, p):
                if tier(i, j) == HI:                  # line 25 dgemm
                    if tier(i, k) != HI:
                        emit(Task("CONVERT", k, (i, k), tier=HI,
                                  src_tier=tier(i, k)))
                    emit(Task("GEMM", k, (i, j),
                              reads=((i, k), (j, k), (i, j)), tier=HI))
                else:                                 # line 27 sgemm
                    for (r, c) in ((i, k), (j, k)):
                        if tier(r, c) != LO:   # lo_matmul's astype(lo):
                            # demotes hi band-panel tiles, promotes lo2
                            emit(Task("CONVERT", k, (r, c), tier=LO,
                                      src_tier=tier(r, c)))
                    if tier(i, j) == LO2:  # store[(i,j)].astype(lo)
                        emit(Task("CONVERT", k, (i, j), tier=LO, src_tier=LO2))
                    emit(Task("GEMM", k, (i, j),
                              reads=((i, k), (j, k), (i, j)), tier=LO))
    return tasks


def panel_dag(p: int, policy: PrecisionPolicy) -> list[Task]:
    """Task stream of core/panel_cholesky.py's banded split-storage engine."""
    if policy.mode == "dst":
        raise ValueError("use dst_dag for the DST baseline")
    t = min(policy.diag_thick, p)
    tasks: list[Task] = []
    emit = tasks.append
    tier = lambda i, j: storage_tier(policy, i, j, variant="panel")

    for k in range(p):
        emit(Task("POTRF", k, (k, k), reads=((k, k),), tier=HI))
        m_t = p - k - 1
        if m_t == 0:
            break
        if k + t <= p - 1:
            emit(Task("CONVERT", k, (k, k), tier=LO, src_tier=HI))  # lkk_lo

        n_band_panel = min(t - 1, m_t)
        for d in range(1, n_band_panel + 1):          # dtrsm on band panel
            emit(Task("TRSM", k, (k + d, k), reads=((k, k), (k + d, k)),
                      tier=HI))
        for i in range(k + t, p):                     # batched strsm
            emit(Task("TRSM", k, (i, k), reads=((k, k), (i, k)), tier=LO))

        # gather c_hi: off rows promoted lo -> hi (off[k+t:, k].astype(hi))
        for i in range(k + t, p):
            emit(Task("CONVERT", k, (i, k), tier=HI, src_tier=LO))

        # hi band updates, sub-diagonals d = 0..t-1 (dsyrk / dgemm)
        for d in range(0, min(t, m_t)):
            for r in range(k + 1 + d, p):             # target tile (r, r-d)
                c = r - d
                kind = "SYRK" if d == 0 else "GEMM"
                emit(Task(kind, k, (r, c), reads=((r, k), (c, k), (r, c)),
                          tier=HI))

        # demote the gathered panel: c_lo = c_hi.astype(lo) -- band rows
        # need an explicit hi -> lo copy (off rows are already stored lo)
        has_off_targets = any(i - j >= t
                              for j in range(k + 1, p) for i in range(j, p))
        if has_off_targets:
            for d in range(1, n_band_panel + 1):
                emit(Task("CONVERT", k, (k + d, k), tier=LO, src_tier=HI))

        # lo off-band update (sgemm over the masked trapezoid)
        for j in range(k + 1, p):
            for i in range(j + t, p):
                emit(Task("GEMM", k, (i, j), reads=((i, k), (j, k), (i, j)),
                          tier=LO))
    return tasks


def dst_dag(p: int, policy: PrecisionPolicy) -> list[Task]:
    """Task stream of the DST baseline: dense Cholesky per super-block.

    Any policy's diag_thick defines the super-block size (the engine takes
    it as a bare int); all math is hi, off-block tiles are dropped.
    """
    bs = min(policy.diag_thick, p)
    tasks: list[Task] = []
    emit = tasks.append
    start = 0
    while start < p:
        stop = min(start + bs, p)
        for k in range(start, stop):                  # dense right-looking
            emit(Task("POTRF", k, (k, k), reads=((k, k),), tier=HI))
            for i in range(k + 1, stop):
                emit(Task("TRSM", k, (i, k), reads=((k, k), (i, k)), tier=HI))
            for j in range(k + 1, stop):
                emit(Task("SYRK", k, (j, j), reads=((j, k), (j, j)), tier=HI))
                for i in range(j + 1, stop):
                    emit(Task("GEMM", k, (i, j),
                              reads=((i, k), (j, k), (i, j)), tier=HI))
        start = stop
    return tasks


VARIANTS = {"tile": tile_dag, "panel": panel_dag, "dst": dst_dag}


def build_dag(variant: str, p: int, policy: PrecisionPolicy) -> list[Task]:
    return VARIANTS[variant](p, policy)


# ---------------------------------------------------------------------------
# dependency structure (shared by the checker and repro.sched's runtime)
# ---------------------------------------------------------------------------

def task_dependencies(tasks: list[Task], p: int, policy: PrecisionPolicy,
                      variant: str) -> list[tuple[int, ...]]:
    """Per-task producer indices, aligned with each task's operand list.

    For a compute task, entry m is the index of the task whose output
    operand ``reads[m]`` consumes: the tile's last writer, or -- when the
    read crosses storage tiers -- the CONVERT that produced the copy being
    read.  For a CONVERT task the single entry is the producer of the
    source value.  ``-1`` marks an initial-storage operand (no producing
    task).  This is the one dependency computation shared by `check_dag`'s
    critical-path DP and the dynamic scheduler (`repro.sched`): an edge
    here IS an edge in the runtime's ready-queue graph.

    Permissive by design: on a corrupted stream a missing producer
    degrades to the tile's last writer / -1, so `check_dag`'s protocol
    state machine (not this helper) reports the violation.
    """
    tier_of = lambda i, j: storage_tier(policy, i, j, variant=variant)
    last_writer: dict[tuple[int, int], int] = {}
    copies: dict[tuple[tuple[int, int], str], int] = {}
    deps: list[tuple[int, ...]] = []
    for idx, task in enumerate(tasks):
        tile = task.target
        if task.kind == "CONVERT":
            if task.src_tier == tier_of(*tile):
                src = last_writer.get(tile, -1)
            else:            # chained copy: source is itself a conversion
                src = copies.get((tile, task.src_tier),
                                 last_writer.get(tile, -1))
            deps.append((src,))
            copies[(tile, task.tier)] = idx
        else:
            row = []
            for r in task.reads:
                if tier_of(*r) in (task.tier, None):
                    row.append(last_writer.get(r, -1))
                else:        # cross-tier read goes through the current copy
                    # (in-place operands too: an lo2-stored tile consumed in
                    # lo reads its CONVERT product, exactly like the
                    # engine's astype(lo) of the accumulator)
                    row.append(copies.get((r, task.tier),
                                          last_writer.get(r, -1)))
            deps.append(tuple(row))
            last_writer[tile] = idx
            for key in [c for c in copies if c[0] == tile]:
                del copies[key]  # a write invalidates stale copies
    return deps


def successor_map(deps: list[tuple[int, ...]]) -> list[list[int]]:
    """Inverse of `task_dependencies`: per-task list of dependent tasks."""
    succs: list[list[int]] = [[] for _ in deps]
    for idx, row in enumerate(deps):
        for d in set(row):
            if d >= 0:
                succs[d].append(idx)
    return succs


def generations(deps: list[tuple[int, ...]]) -> list[list[int]]:
    """Bucket task indices by longest-dependency-chain depth.

    Generation g holds every task whose longest producer chain has g
    tasks before it -- the maximal wavefronts a dependency-respecting
    runtime may execute concurrently.  Emission order is topological, so
    a single forward pass suffices.
    """
    depth = [0] * len(deps)
    for idx, row in enumerate(deps):
        depth[idx] = max((depth[d] + 1 for d in row if d >= 0), default=0)
    gens: list[list[int]] = [[] for _ in range(max(depth, default=-1) + 1)]
    for idx, d in enumerate(depth):
        gens[d].append(idx)
    return gens


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _TileState:
    next_update: int       # next expected update step k
    factor_step: int       # step at which the factor op lands (== column j)
    factored: bool = False
    version: int = 0       # bumped on every write
    copies: dict = dataclasses.field(default_factory=dict)  # tier -> version


@dataclasses.dataclass
class DagReport:
    variant: str
    p: int
    policy_label: str
    n_tasks: int
    n_converts: int
    tier_flops: dict[str, float]         # units of nb^3, per exec tier
    convert_tiles: dict[str, int]        # "src->dst" -> tile count
    critical_path_flops: float           # units of nb^3 along longest chain
    critical_path_tasks: int

    @property
    def total_flops(self) -> float:
        return sum(self.tier_flops.values())

    def tier_fractions(self) -> dict[str, float]:
        tot = self.total_flops or 1.0
        return {t: f / tot for t, f in self.tier_flops.items()}


def check_dag(tasks: list[Task], p: int, policy: PrecisionPolicy,
              variant: str, *, label: str | None = None) -> DagReport:
    """Verify hazard freedom + precision-edge consistency; return the report.

    Raises HazardError naming the first offending task otherwise.
    """
    tier_of = lambda i, j: storage_tier(policy, i, j, variant=variant)

    live: dict[tuple[int, int], _TileState] = {}
    for i in range(p):
        for j in range(i + 1):
            st = tier_of(i, j)
            if st is None:
                continue
            if variant == "dst":
                first_k = (j // policy.diag_thick) * policy.diag_thick
            else:
                first_k = 0
            live[(i, j)] = _TileState(next_update=first_k, factor_step=j)

    def fail(task, why):
        raise HazardError(f"{variant} p={p} {label or policy.mode}: "
                          f"{why} at task {task}")

    # --- replay: protocol state machine + conversion-copy tracking ---------
    # dependency edges come from the shared helper so the checker's critical
    # path and the dynamic scheduler (repro.sched) see the same graph
    deps_list = task_dependencies(tasks, p, policy, variant)
    cp_flops: list[float] = []
    cp_tasks: list[int] = []
    tier_flops: dict[str, float] = {}
    convert_tiles: dict[str, int] = {}

    for idx, task in enumerate(tasks):
        tile = task.target
        if tile not in live:
            fail(task, f"touches dropped/out-of-range tile {tile}")
        st = live[tile]

        if task.kind == "CONVERT":
            if task.src_tier == task.tier:
                fail(task, "no-op conversion")
            src_store = tier_of(*tile)
            if task.src_tier != src_store \
                    and st.copies.get(task.src_tier) != st.version:
                fail(task, f"CONVERT from {task.src_tier} but tile is stored "
                           f"as {src_store} with no current {task.src_tier} "
                           "copy")
            # a copy snapshots the CURRENT canonical version
            st.copies[task.tier] = st.version
            key = f"{task.src_tier}->{task.tier}"
            convert_tiles[key] = convert_tiles.get(key, 0) + 1
            flops = 0.0
        else:
            # 1. precision-edge consistency on every read
            for r in task.reads:
                if r not in live:
                    fail(task, f"reads dropped tile {r}")
                rst = live[r]
                r_store = tier_of(*r)
                if r == tile:
                    pass           # in-place operand: storage tier by def.
                elif r_store != task.tier:
                    cv = rst.copies.get(task.tier)
                    if cv != rst.version:
                        fail(task, f"consumes {r_store}-stored tile {r} in "
                                   f"{task.tier} without a current CONVERT "
                                   "(missing dlag2s/sconv2d)")
                # 2. RAW: panel operands (column == task.k) must be factored
                if r != tile and r[1] == task.k and task.kind in ("SYRK", "GEMM"):
                    if not rst.factored:
                        fail(task, f"RAW: reads unfactored panel tile {r}")
                if r[1] == task.k and task.kind == "TRSM" and r == (task.k, task.k):
                    if not rst.factored:
                        fail(task, f"RAW: TRSM before POTRF of {r}")

            # 3. protocol / WAR / WAW on the written tile
            i, j = tile
            if task.kind in ("SYRK", "GEMM"):
                if st.factored:
                    fail(task, f"WAR: update of already-factored tile {tile}")
                if task.k != st.next_update:
                    if task.k < st.next_update:
                        fail(task, f"WAW: duplicate/out-of-order update "
                                   f"k={task.k} (expected k={st.next_update})")
                    fail(task, f"RAW: update k={task.k} skips pending "
                               f"update k={st.next_update}")
                st.next_update += 1
            elif task.kind in ("POTRF", "TRSM"):
                if st.factored:
                    fail(task, f"WAW: tile {tile} factored twice")
                if task.k != st.factor_step:
                    fail(task, f"factor op at step {task.k}, tile belongs "
                               f"to column {st.factor_step}")
                if st.next_update != st.factor_step:
                    fail(task, f"RAW: factor before update "
                               f"k={st.next_update} was applied")
                if task.kind == "POTRF" and i != j:
                    fail(task, "POTRF off the diagonal")
                if task.kind == "TRSM" and i == j:
                    fail(task, "TRSM on the diagonal")
                st.factored = True
            else:
                fail(task, f"unknown task kind {task.kind}")
            st.version += 1
            st.copies.clear()      # a write invalidates every stale copy
            flops = _FLOP_UNITS[task.kind]
            tier_flops[task.tier] = tier_flops.get(task.tier, 0.0) + flops

        # critical path DP over RAW/WAW edges (emission order = topo order);
        # flops-longest and tasks-longest chains are tracked independently
        deps = deps_list[idx]
        best_f = max((cp_flops[d] for d in deps if d >= 0), default=0.0)
        best_t = max((cp_tasks[d] for d in deps if d >= 0), default=0)
        cp_flops.append(best_f + flops)
        cp_tasks.append(best_t + (0 if task.kind == "CONVERT" else 1))

    # --- completeness: every live tile fully updated and factored ----------
    for tile, st in live.items():
        if not st.factored:
            raise HazardError(f"{variant} p={p} {label or policy.mode}: tile "
                              f"{tile} never factored (missing POTRF/TRSM)")
        if st.next_update != st.factor_step:
            raise HazardError(f"{variant} p={p} {label or policy.mode}: tile "
                              f"{tile} missing update k={st.next_update}")

    return DagReport(
        variant=variant, p=p, policy_label=label or policy.mode,
        n_tasks=sum(1 for t in tasks if t.kind != "CONVERT"),
        n_converts=sum(1 for t in tasks if t.kind == "CONVERT"),
        tier_flops=tier_flops, convert_tiles=convert_tiles,
        critical_path_flops=max(cp_flops, default=0.0),
        critical_path_tasks=max(cp_tasks, default=0))


def analyze(variant: str, p: int, policy: PrecisionPolicy, *,
            label: str | None = None) -> DagReport:
    """Build + check one variant's DAG; raises HazardError on violation."""
    return check_dag(build_dag(variant, p, policy), p, policy, variant,
                     label=label)


def flop_report(n: int, nb: int, policy: PrecisionPolicy,
                variant: str = "tile") -> dict[str, float]:
    """Per-tier FLOP counts (actual FLOPs, not nb^3 units) for an (n, n)
    factorization -- the costmodel/benchmarks entry point."""
    assert n % nb == 0, (n, nb)
    p = n // nb
    rep = analyze(variant, p, policy)
    unit = float(nb) ** 3
    out = {f"{t}_flops": f * unit for t, f in rep.tier_flops.items()}
    out["total_flops"] = rep.total_flops * unit
    out["critical_path_flops"] = rep.critical_path_flops * unit
    out["critical_path_tasks"] = float(rep.critical_path_tasks)
    for t in (HI, LO, LO2):
        out.setdefault(f"{t}_flops", 0.0)
        out[f"{t}_frac"] = out[f"{t}_flops"] / max(out["total_flops"], 1.0)
    out["convert_tiles"] = float(sum(rep.convert_tiles.values()))
    return out
