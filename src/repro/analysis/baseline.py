"""Committed suppression baseline for grandfathered linter findings.

A finding is baselined by the key (rule, path, stripped source line) --
line numbers shift too easily to key on.  Every entry must carry a
non-empty human reason; the reason is the reviewable artifact (the same
contract as verify/golden's committed accuracy JSON).

Flow:
  * `python -m repro.analysis --check` fails on any finding that is not
    baselined and not pragma-suppressed;
  * after an INTENDED new suppression, add the entry by hand (preferred,
    forces writing the reason) or run `--update-baseline` and fill in the
    generated "TODO" reasons before committing -- the checker rejects a
    baseline containing TODO reasons.
"""

from __future__ import annotations

import json
from pathlib import Path

from .lint import Finding

BASELINE_PATH = Path(__file__).parent / "baseline.json"


def load_baseline(path: Path = BASELINE_PATH) -> list[dict]:
    if not Path(path).exists():
        return []
    entries = json.loads(Path(path).read_text())["findings"]
    for e in entries:
        if not e.get("reason", "").strip() or "TODO" in e.get("reason", ""):
            raise ValueError(
                f"baseline entry for {e.get('path')}:{e.get('code', '')!r} "
                "has an empty/TODO reason; every suppression needs a real one")
    return entries


def _key(rule: str, path: str, code: str) -> tuple[str, str, str]:
    return (rule, path, " ".join(code.split()))


def split_baselined(findings: list[Finding], entries: list[dict]):
    """-> (new_findings, baselined_findings, unused_entries)."""
    allowed = {_key(e["rule"], e["path"], e["code"]) for e in entries}
    used: set[tuple[str, str, str]] = set()
    new, old = [], []
    for f in findings:
        k = _key(f.rule, f.path, f.code)
        if k in allowed:
            used.add(k)
            old.append(f)
        else:
            new.append(f)
    unused = [e for e in entries
              if _key(e["rule"], e["path"], e["code"]) not in used]
    return new, old, unused


def update_baseline(findings: list[Finding], path: Path = BASELINE_PATH) -> int:
    """Rewrite the baseline to exactly the current findings, keeping any
    existing reasons; new entries get a "TODO" reason the check rejects
    until a human fills it in."""
    try:
        existing = {_key(e["rule"], e["path"], e["code"]): e["reason"]
                    for e in json.loads(Path(path).read_text())["findings"]}
    except (FileNotFoundError, KeyError, json.JSONDecodeError):
        existing = {}
    entries, seen = [], set()
    for f in sorted(findings, key=lambda f: (f.path, f.line)):
        k = _key(f.rule, f.path, f.code)
        if k in seen:
            continue
        seen.add(k)
        entries.append({
            "rule": f.rule, "path": f.path, "code": " ".join(f.code.split()),
            "reason": existing.get(k, "TODO: justify this suppression"),
        })
    Path(path).write_text(json.dumps({"findings": entries}, indent=2) + "\n")
    return len(entries)
