"""Happens-before trace verifier: vector clocks over recorded schedules.

The dynamic runtime (`repro.sched`) argues race freedom by construction:
values are write-once keyed by producer index, and the ready queue only
releases a task once every producer has published.  This module checks
that claim *against evidence* -- a recorded execution (a `SchedReport`,
or the Chrome trace JSON the runtime writes and CI uploads) -- the way a
happens-before race detector checks a real program:

  1.  Rebuild the ground-truth dependency graph for the trace's
      (variant, p, policy) cell from `analysis.dag.task_dependencies` --
      the same edges the scheduler's ready queue enforces.
  2.  Reconstruct the execution's own ordering: per-worker program order
      (events on one worker track, sorted by time; `validate_trace`
      already guarantees they never overlap) plus every dependency edge
      the recorded timestamps actually respect.
  3.  Assign a vector clock to every task event over the worker tracks
      and verify three properties:

      * dependency order -- task B reading task A's output must start at
        or after A's end (a violation means the runtime released B while
        A was still in flight: a real race, or a dropped edge);
      * conversion order -- a cross-tier read must be fed by a CONVERT of
        the current version, and that CONVERT must happen-before the
        consumer (the paper's dlag2s/sconv2d discipline, dynamically);
      * write-write order -- any two writes to the same tile slot (the
        canonical tile for compute tasks, the (tile, tier) copy slot for
        CONVERTs) must be HB-ordered one way or the other.  One
        refinement mirrors the runtime's write-once value store:
        duplicate CONVERTs of the SAME source version (the stream emits
        one per consumer; each is an independent, bitwise-identical
        immutable copy keyed by its own task index) need no mutual
        order, but CONVERTs of *different* versions of a tile into the
        same tier slot do.

Violations are reported as (task A, task B, tile, missing edge), naming
the workers by their recorded thread names.

The model is exact, not sampled: with one event per task and HB edges
from program order + respected dependencies, `VC[b][track(a)] >=
VC[a][track(a)]` is equivalent to "a happens-before b" (standard vector-
clock semantics), so a reported pair is a genuine unordered pair under
the recorded schedule.
"""

from __future__ import annotations

import dataclasses

from ..dag import Task, task_dependencies


@dataclasses.dataclass(frozen=True)
class _Event:
    """One recorded task execution, normalized from either input form."""
    index: int                 # task index in emission order
    worker: object             # track key (worker id or tid)
    worker_name: str
    start: float
    end: float


@dataclasses.dataclass(frozen=True)
class HBViolation:
    kind: str                  # "dep-order" | "convert-order" | "write-write"
    task_a: str                # producer / first writer (str(Task))
    task_b: str                # consumer / second writer
    index_a: int
    index_b: int
    tile: tuple | None         # tile slot in conflict (None: structural)
    missing_edge: str          # human-readable description of the gap

    def render(self) -> str:
        return (f"[{self.kind}] {self.task_a} (#{self.index_a}) vs "
                f"{self.task_b} (#{self.index_b}) on tile {self.tile}: "
                f"{self.missing_edge}")


class HBError(ValueError):
    """The trace cannot be checked at all (wrong cell, missing events)."""


@dataclasses.dataclass(frozen=True)
class HBReport:
    variant: str
    p: int
    n_events: int
    n_dep_edges: int           # ground-truth dependency edges checked
    n_po_edges: int            # per-worker program-order edges
    n_write_pairs: int         # same-slot write pairs checked for HB order
    violations: tuple[HBViolation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        head = (f"hb {self.variant} p={self.p}: {self.n_events} events, "
                f"{self.n_dep_edges} dep edges + {self.n_po_edges} program-"
                f"order edges, {self.n_write_pairs} write pairs, "
                f"{len(self.violations)} violations")
        return "\n".join([head] + [f"  {v.render()}" for v in self.violations])


# ---------------------------------------------------------------------------
# event extraction
# ---------------------------------------------------------------------------

def _events_from_report(report) -> list[_Event]:
    return [_Event(index=ev.index, worker=ev.worker,
                   worker_name=getattr(ev, "worker_name", "") or
                   f"worker{ev.worker}",
                   start=ev.start, end=ev.end)
            for ev in report.events]


def _events_from_trace(trace: dict) -> list[_Event]:
    """Scheduler task events from a Chrome trace (pid 0, complete events
    carrying a task index; merged traces' host spans on pid 1 are ignored)."""
    raw = [ev for ev in trace.get("traceEvents", [])
           if isinstance(ev, dict) and ev.get("pid") == 0]
    names = {ev.get("tid"): ev.get("args", {}).get("name", "")
             for ev in raw
             if ev.get("ph") == "M" and ev.get("name") == "thread_name"}
    out = []
    for ev in raw:
        if ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        if "index" not in args:
            continue
        tid = ev.get("tid")
        out.append(_Event(
            index=int(args["index"]), worker=tid,
            worker_name=args.get("worker") or names.get(tid) or str(tid),
            start=float(ev["ts"]), end=float(ev["ts"]) + float(ev["dur"])))
    return out


def graph_from_trace(trace: dict):
    """Rebuild the TaskGraph named by a trace's otherData (variant, p,
    policy mode/thresholds); raises HBError when the trace predates the
    metadata."""
    from ...core.precision import PrecisionPolicy
    from ...sched.runtime import build_graph

    other = trace.get("otherData", {})
    variant, p = other.get("variant"), other.get("p")
    pol = other.get("policy")
    if not variant or not p or not isinstance(pol, dict):
        raise HBError(
            "trace otherData lacks variant/p/policy -- re-emit the trace "
            "with a current `python -m repro.sched`, or pass the graph "
            "explicitly")
    mode = pol.get("mode")
    d1, d2 = int(pol.get("diag_thick", 1)), int(pol.get("diag_thick2", 0))
    if mode == "full":
        policy = PrecisionPolicy.full()
    elif mode == "mixed":
        policy = PrecisionPolicy.tpu(d1)
    elif mode == "dst":
        policy = PrecisionPolicy.dst(d1)
    elif mode == "three_tier":
        policy = PrecisionPolicy.three_tier(d1, d2)
    else:
        raise HBError(f"trace names unknown policy mode {mode!r}")
    return build_graph(variant, int(p), policy)


# ---------------------------------------------------------------------------
# the verifier
# ---------------------------------------------------------------------------

def _write_slots(tasks) -> dict[object, list[int]]:
    """Slot key -> ordered writer task indices.

    Compute tasks write the canonical tile store slot `("tile", i, j)`;
    CONVERTs write the copy slot `("copy", i, j, dst_tier)` -- the same
    slot partitioning `analysis.dag.check_dag` replays.
    """
    slots: dict[object, list[int]] = {}
    for idx, t in enumerate(tasks):
        if t.kind == "CONVERT":
            key = ("copy", *t.target, t.tier)
        else:
            key = ("tile", *t.target)
        slots.setdefault(key, []).append(idx)
    return slots


def _same_version_copies(tasks, deps, a: int, b: int) -> bool:
    """True when two CONVERTs snapshot the same immutable source value
    (same producer), i.e. are bitwise-identical independent copies."""
    return (tasks[a].kind == "CONVERT" and tasks[b].kind == "CONVERT"
            and set(deps[a]) == set(deps[b]))


def verify_events(events: list[_Event], graph, *, atol: float = 0.0) -> HBReport:
    """Run the HB checks over normalized events against `graph`'s edges.

    `atol` is a timestamp slack for clock granularity (virtual-time sim
    traces are exact; real traces use one perf_counter, so 0.0 is right
    there too -- the knob exists for imported traces with coarse clocks).
    """
    tasks: tuple[Task, ...] = tuple(graph.tasks)
    n = len(tasks)
    by_index: dict[int, _Event] = {}
    for e in events:
        if e.index in by_index:
            raise HBError(f"task #{e.index} recorded twice in the trace")
        by_index[e.index] = e
    missing = [i for i in range(n) if i not in by_index]
    extra = sorted(set(by_index) - set(range(n)))
    if missing or extra:
        raise HBError(
            f"trace does not cover the graph: missing task indices "
            f"{missing[:8]}{'...' if len(missing) > 8 else ''}, unknown "
            f"indices {extra[:8]}")

    deps = graph.deps if hasattr(graph, "deps") else tuple(
        task_dependencies(list(tasks), graph.p, graph.policy, graph.variant))

    violations: list[HBViolation] = []

    def viol(kind, a, b, tile, msg):
        violations.append(HBViolation(
            kind=kind, task_a=str(tasks[a]), task_b=str(tasks[b]),
            index_a=a, index_b=b, tile=tile, missing_edge=msg))

    # --- 1. dependency order: producer must end before consumer starts ----
    n_dep_edges = 0
    respected: list[tuple[int, int]] = []    # HB edges the trace backs up
    for idx in range(n):
        ea = by_index[idx]
        for d in set(deps[idx]):
            if d < 0:
                continue
            n_dep_edges += 1
            ep = by_index[d]
            if ep.end <= ea.start + atol:
                respected.append((d, idx))
            else:
                kind = ("convert-order" if tasks[d].kind == "CONVERT"
                        else "dep-order")
                viol(kind, d, idx, tasks[d].target,
                     f"{ep.worker_name} ended #{d} at t={ep.end:.6g} but "
                     f"{ea.worker_name} started #{idx} at t={ea.start:.6g} "
                     f"(missing edge #{d} -> #{idx})")

    # --- 2. vector clocks from program order + respected dep edges --------
    tracks = sorted({e.worker for e in events}, key=str)
    track_of = {w: i for i, w in enumerate(tracks)}
    per_track: dict[object, list[_Event]] = {w: [] for w in tracks}
    for e in events:
        per_track[e.worker].append(e)
    n_po_edges = 0
    preds: list[list[int]] = [[] for _ in range(n)]
    for w, evs in per_track.items():
        evs.sort(key=lambda e: (e.start, e.end, e.index))
        for a, b in zip(evs, evs[1:]):
            preds[b.index].append(a.index)
            n_po_edges += 1
    for d, idx in respected:
        preds[idx].append(d)

    # events sorted by start time are a topological order of the HB graph:
    # every HB edge runs from an event that ended at or before its
    # successor's start (program order by non-overlap, dep edges by the
    # `respected` filter above)
    order = sorted(range(n), key=lambda i: (by_index[i].start,
                                            by_index[i].end, i))
    vc: list[list[int] | None] = [None] * n
    count_on_track = {w: 0 for w in tracks}
    for idx in order:
        e = by_index[idx]
        clock = [0] * len(tracks)
        for pidx in preds[idx]:
            pv = vc[pidx]
            if pv is None:      # predecessor starts later: not HB, skip
                continue
            for i, v in enumerate(pv):
                if v > clock[i]:
                    clock[i] = v
        t = track_of[e.worker]
        count_on_track[e.worker] += 1
        clock[t] = count_on_track[e.worker]
        vc[idx] = clock

    def hb(a: int, b: int) -> bool:
        ta = track_of[by_index[a].worker]
        return vc[b][ta] >= vc[a][ta]    # type: ignore[index]

    # --- 3. write-write order on every slot -------------------------------
    n_write_pairs = 0
    for slot, writers in _write_slots(tasks).items():
        for i, a in enumerate(writers):
            for b in writers[i + 1:]:
                if _same_version_copies(tasks, deps, a, b):
                    continue    # bitwise-identical duplicate copies
                n_write_pairs += 1
                if not (hb(a, b) or hb(b, a)):
                    viol("write-write", a, b, slot[1:3],
                         f"writes to slot {slot} on "
                         f"{by_index[a].worker_name} and "
                         f"{by_index[b].worker_name} are concurrent "
                         f"(no HB edge either way)")

    return HBReport(
        variant=graph.variant, p=graph.p, n_events=n,
        n_dep_edges=n_dep_edges, n_po_edges=n_po_edges,
        n_write_pairs=n_write_pairs, violations=tuple(violations))


def verify_sched_report(report, graph=None, *, atol: float = 0.0) -> HBReport:
    """Verify a `sched.runtime.SchedReport` directly (no file round-trip)."""
    if graph is None:
        graph = _graph_for_report(report)
    return verify_events(_events_from_report(report), graph, atol=atol)


def verify_trace(trace: dict, graph=None, *, atol: float = 0.0) -> HBReport:
    """Verify a Chrome trace dict (plain or merged); rebuilds the graph
    from otherData unless one is passed."""
    if graph is None:
        graph = graph_from_trace(trace)
    return verify_events(_events_from_trace(trace), graph, atol=atol)


def verify_trace_file(path, graph=None, *, atol: float = 0.0) -> HBReport:
    import json

    with open(path) as fh:
        return verify_trace(json.load(fh), graph, atol=atol)


def _graph_for_report(report):
    from ...sched.runtime import build_graph

    trace_shim = {"otherData": {
        "variant": report.variant, "p": getattr(report, "p", 0),
        "policy": dict(zip(("mode", "diag_thick", "diag_thick2"),
                           getattr(report, "policy", ()))),
    }}
    try:
        return graph_from_trace(trace_shim)
    except HBError:
        raise HBError(
            "report carries no (p, policy) metadata; pass the TaskGraph "
            "explicitly") from None
