"""Lock-discipline linter: lockset analysis over the annotated shared state.

The threaded executor (`sched/runtime.py`) and the telemetry recorder
(`obs/recorder.py`) both follow a single-lock discipline: every mutation
of shared state happens inside one ``with <lock>:`` block.  That
discipline is exactly the kind of invariant that silently rots -- a new
code path appends to ``state.events`` outside the lock and nothing fails
until a trace shows overlapping events on one worker.  This module makes
the discipline machine-checked, three rules strong:

  guarded-by
      Attributes declared with a ``# repro: guarded-by=<lock>`` comment on
      their initialization line form the registry.  Any later mutation of
      a registered attribute -- plain/augmented/subscript assignment, a
      mutating method call (``.append``/``.clear``/...), or a ``heapq``
      operation on it -- must sit lexically inside a ``with`` block whose
      context expression's trailing name is the declared lock (a Condition
      constructed over the lock counts: ``with state.cond:`` guards
      ``guarded-by=cond`` attributes).  Exemptions: ``__init__`` /
      ``__post_init__`` bodies (construction happens-before publication)
      and methods named ``*_locked`` (contract: caller holds the lock).
      Calling a ``*_locked`` method outside the lock is itself a finding.

  cv-wait-loop
      Every condition-variable ``.wait()`` must sit inside a ``while``
      loop (re-check the predicate after wakeup: spurious wakeups and
      notify_all races are real).  An ``if``-guarded wait is a finding.

  lock-dispatch
      No JAX dispatch while holding a registered lock: inside a ``with
      <registered lock>:`` block, calls into ``jnp``/``jax``/``lax``,
      ``*.block_until_ready()``, or ``kernels.run(...)`` are findings.
      Kernel execution under the scheduler lock serializes the worker
      pool (and can deadlock if the computation ever re-enters the
      scheduler); the executor deliberately computes outside the lock and
      publishes inside it.

Findings reuse `analysis.lint`'s `Finding` type, per-line ``# repro:
disable=<rule> -- reason`` pragmas, and the committed baseline, so the
CLI gate (`python -m repro.analysis --check --concurrency`) treats them
exactly like precision-flow findings.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from ..lint import Finding, pragma_lines, _suppressed

LOCKGUARD_RULES = ("guarded-by", "cv-wait-loop", "lock-dispatch")

#: files the lock discipline applies to (repo-relative under src/)
LOCKGUARD_FILES = ("repro/sched/runtime.py", "repro/obs/recorder.py")

_GUARD_RE = re.compile(r"#\s*repro:\s*guarded-by=([A-Za-z_][A-Za-z0-9_]*)")

# method names that mutate their receiver in place
MUTATOR_METHODS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "add", "discard", "update", "setdefault", "appendleft", "popleft",
    "sort", "reverse",
})
# module-level functions whose FIRST argument is mutated in place
ARG_MUTATORS = frozenset({"heappush", "heappop", "heapify", "heapreplace",
                          "heappushpop"})

DISPATCH_MODULES = frozenset({"jnp", "jax", "lax"})
DISPATCH_METHODS = frozenset({"block_until_ready"})


def _trailing_name(node: ast.AST) -> str | None:
    """`state.cond` -> "cond", `self._lock` -> "_lock", `cond` -> "cond"."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _root_name(node: ast.AST) -> str | None:
    """`kernels.run` -> "kernels", `a.b.c` -> "a"."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def guarded_registry(source: str) -> dict[str, str]:
    """attr name -> lock name, from `# repro: guarded-by=<lock>` comments.

    The comment must sit on a line that assigns `<obj>.<attr>` (the
    declaration site, normally in __init__).
    """
    registry: dict[str, str] = {}
    guards = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _GUARD_RE.search(text)
        if m:
            guards[i] = m.group(1)
    if not guards:
        return registry
    tree = ast.parse(source)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            continue
        lock = None
        for ln in range(node.lineno, (node.end_lineno or node.lineno) + 1):
            if ln in guards:
                lock = guards[ln]
                break
        if lock is None:
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for tgt in targets:
            if isinstance(tgt, ast.Attribute):
                registry[tgt.attr] = lock
    return registry


# ---------------------------------------------------------------------------
# the analysis
# ---------------------------------------------------------------------------

class _Analyzer:
    def __init__(self, source: str, relpath: str):
        self.source = source
        self.relpath = relpath
        self.lines = source.splitlines()
        self.pragmas = pragma_lines(source)
        self.registry = guarded_registry(source)
        self.tree = ast.parse(source)
        self.findings: list[Finding] = []
        self.parents: dict[int, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[id(child)] = parent

    def flag(self, rule: str, node: ast.AST, msg: str) -> None:
        if _suppressed(self.pragmas, node, rule):
            return
        self.findings.append(Finding(
            rule, self.relpath, node.lineno, msg,
            self.lines[node.lineno - 1].strip()))

    # --- context helpers ---------------------------------------------------
    def _ancestors(self, node: ast.AST):
        cur = self.parents.get(id(node))
        while cur is not None:
            yield cur
            cur = self.parents.get(id(cur))

    def _held_locks(self, node: ast.AST) -> set[str]:
        """Trailing names of every `with`-context lock held at `node`."""
        held: set[str] = set()
        for anc in self._ancestors(node):
            if isinstance(anc, (ast.With, ast.AsyncWith)):
                for item in anc.items:
                    name = _trailing_name(item.context_expr)
                    if name:
                        held.add(name)
        return held

    def _enclosing_function(self, node: ast.AST):
        for anc in self._ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def _exempt_context(self, node: ast.AST) -> bool:
        fn = self._enclosing_function(node)
        return fn is not None and (
            fn.name in ("__init__", "__post_init__")
            or fn.name.endswith("_locked"))

    # --- mutation extraction ----------------------------------------------
    def _mutated_attr(self, node: ast.AST) -> tuple[str, ast.AST] | None:
        """Registered attribute this node mutates, or None.

        Recognizes `x.attr = v`, `x.attr += v`, `x.attr[k] = v`,
        `x.attr.append(v)` (and friends), and `heappush(x.attr, v)`.
        """
        def attr_of(tgt: ast.AST) -> str | None:
            if isinstance(tgt, ast.Subscript):
                tgt = tgt.value
            if isinstance(tgt, ast.Attribute) and tgt.attr in self.registry:
                return tgt.attr
            return None

        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                a = attr_of(tgt)
                if a:
                    return a, node
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATOR_METHODS:
                a = attr_of(node.func.value)
                if a:
                    return a, node
            fname = _trailing_name(node.func)
            if fname in ARG_MUTATORS and node.args:
                a = attr_of(node.args[0])
                if a:
                    return a, node
        return None

    # --- rule passes -------------------------------------------------------
    def check_guarded_by(self) -> None:
        for node in ast.walk(self.tree):
            hit = self._mutated_attr(node)
            if hit is None:
                continue
            attr, site = hit
            if self._exempt_context(site):
                continue
            lock = self.registry[attr]
            if lock not in self._held_locks(site):
                self.flag(
                    "guarded-by", site,
                    f"mutation of {attr!r} outside `with {lock}:` "
                    f"(declared # repro: guarded-by={lock})")
        # *_locked helpers must themselves be called under the lock
        locked_fns = {
            fn.name for fn in ast.walk(self.tree)
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
            and fn.name.endswith("_locked")}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = _trailing_name(node.func)
            if fname not in locked_fns:
                continue
            if self._exempt_context(node) or self._held_locks(node):
                continue
            self.flag(
                "guarded-by", node,
                f"call of lock-held-contract helper {fname!r} outside any "
                "`with <lock>:` block")

    def check_cv_wait(self) -> None:
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("wait", "wait_for")):
                continue
            recv = _trailing_name(node.func.value) or ""
            if "cond" not in recv and recv not in self.registry.values():
                continue
            if node.func.attr == "wait_for":
                continue     # wait_for re-checks its predicate internally
            if not any(isinstance(a, ast.While) for a in self._ancestors(node)):
                self.flag(
                    "cv-wait-loop", node,
                    f"{recv}.wait() outside a while loop -- condition waits "
                    "must re-check their predicate after wakeup (spurious "
                    "wakeups, notify_all races)")

    def check_lock_dispatch(self) -> None:
        lock_names = set(self.registry.values())
        if not lock_names:
            return
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            held = self._held_locks(node) & lock_names
            if not held:
                continue
            root = _root_name(node.func)
            is_dispatch = (
                root in DISPATCH_MODULES
                or (isinstance(node.func, ast.Attribute)
                    and node.func.attr in DISPATCH_METHODS)
                or (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "run"
                    and _trailing_name(node.func.value) == "kernels"))
            if is_dispatch:
                self.flag(
                    "lock-dispatch", node,
                    f"JAX dispatch under the {sorted(held)[0]!r} lock -- "
                    "compute outside the lock, publish inside it")

    def run(self) -> list[Finding]:
        self.check_guarded_by()
        self.check_cv_wait()
        self.check_lock_dispatch()
        return self.findings


def lockguard_source(source: str, relpath: str) -> list[Finding]:
    """Lint one module's source text against the three lockset rules."""
    return _Analyzer(source, relpath).run()


def lockguard_files(src_root: Path, files=LOCKGUARD_FILES) -> list[Finding]:
    """Lint the registered concurrency-critical modules under src_root
    (the .../src/repro directory)."""
    src_root = Path(src_root)
    findings: list[Finding] = []
    for rel in files:
        path = src_root.parent / rel
        if not path.exists():
            findings.append(Finding(
                "guarded-by", rel, 1,
                "registered lockguard file is missing", ""))
            continue
        findings.extend(lockguard_source(path.read_text(), rel))
    return findings
