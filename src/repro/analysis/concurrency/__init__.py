"""Concurrency soundness layer (DESIGN.md §14).

Third analysis layer next to the precision-flow linter (`analysis.lint`)
and the tile-DAG hazard checker (`analysis.dag`), aimed at the dynamic
task runtime (`repro.sched`) and the telemetry recorder (`repro.obs`):

  * `hb`         -- vector-clock happens-before model over recorded
    traces: every task must start after all of its dependencies end,
    CONVERTs must happen-before their cross-tier consumers, and any two
    writes to the same tile slot must be HB-ordered;
  * `lockguard`  -- AST lockset linter enforcing the
    ``# repro: guarded-by=<lock>`` annotation registry, wait-in-a-loop
    condition-variable discipline, and no-JAX-dispatch-under-the-
    scheduler-lock;
  * `interleave` -- deterministic interleaving model checker: the
    executor's worker loop re-run under a step-controlled cooperative
    stepper across seeded-random and adversarial schedules, asserting
    write-once discipline and bitwise equality with sequential replay.

All three are wired into ``python -m repro.analysis --check
--concurrency`` and the blocking static-analysis CI job.
"""

from .hb import HBReport, HBViolation, verify_sched_report, verify_trace
from .interleave import (
    InterleaveViolation,
    MatrixReport,
    RunResult,
    SCHEDULES,
    explore,
    run_matrix,
)
from .lockguard import LOCKGUARD_RULES, lockguard_files, lockguard_source

__all__ = [
    "HBReport",
    "HBViolation",
    "InterleaveViolation",
    "LOCKGUARD_RULES",
    "MatrixReport",
    "RunResult",
    "SCHEDULES",
    "explore",
    "lockguard_files",
    "lockguard_source",
    "run_matrix",
    "verify_sched_report",
    "verify_trace",
]
