"""Interleaving model checker: the executor's worker loop, step-controlled.

`sched.runtime.execute` lets the OS scheduler pick which worker acquires
the lock next, so any single threaded run exercises ONE interleaving out
of exponentially many.  This module re-runs the same logical worker loop
under a deterministic cooperative stepper: each logical worker is a
three-phase state machine mirroring the real executor's critical
sections --

    pop      (lock held)   pop the best ready task, record dispatch,
                           fetch operand values;
    compute  (lock free)   run the per-tile kernel on the fetched values;
    publish  (lock held)   store the output write-once, decrement
                           successor dependency counts, wake the queue --

and a schedule strategy chooses which runnable worker advances at every
step.  Because the stepper controls the interleaving exactly, a run is
reproducible from (`SchedConfig.seed`, schedule name, salt) alone, and
adversarial schedules can force the orderings a stress test only hits by
luck:

    random            seeded uniform choice among runnable workers;
    reverse_priority  always advance the worker holding the WORST
                      priority-key task (delays critical-path publishes);
    convert_last      starve workers executing CONVERT tasks (stresses
                      cross-tier consumers waiting on dlag2s/sconv2d);
    starve0           worker 0 only advances when it is the sole runnable
                      worker (models an arbitrarily slow OS thread).

Every run asserts the runtime's two safety invariants at the exact point
the real executor relies on them -- operands are present when fetched
(no use-before-publish) and every value slot is written exactly once --
and every completed run must reproduce the in-order sequential replay of
the same kernels bitwise (for the tile variant, additionally the
sequential engine itself).  `run_matrix` sweeps the
(variant x policy x p) conformance matrix and counts DISTINCT explored
interleavings by step signature; the CLI gate requires >= 200 of them,
all clean.
"""

from __future__ import annotations

import dataclasses
import heapq
import random

import numpy as np

from ...sched.config import SchedConfig
from ...sched.runtime import TaskGraph, build_graph, priority_keys

SCHEDULES = ("random", "reverse_priority", "convert_last", "starve0")

_POP, _COMPUTE, _PUBLISH = "pop", "compute", "publish"


class InterleaveViolation(AssertionError):
    """A runtime safety invariant broke under an explored interleaving."""


@dataclasses.dataclass
class _Worker:
    wid: int
    phase: str = _POP          # _POP (idle) | _COMPUTE | _PUBLISH
    task: int = -1
    ops: list | None = None
    out: object = None


@dataclasses.dataclass(frozen=True)
class RunResult:
    schedule: str
    seed: int
    salt: int
    workers: int
    signature: tuple          # ((wid, action, task), ...) -- the interleaving
    dispatch: tuple[int, ...]
    values: tuple             # per-task outputs, emission-indexed

    @property
    def n_steps(self) -> int:
        return len(self.signature)


def _fetch(graph: TaskGraph, kernels, values: list, idx: int) -> list:
    """Operand fetch with the use-before-publish check the real executor
    relies on the ready queue to make unnecessary."""
    task = graph.tasks[idx]
    reads = task.reads if task.kind != "CONVERT" else (task.target,)
    if len(reads) != len(graph.deps[idx]):
        raise InterleaveViolation(
            f"operand arity mismatch: task #{idx} {task} reads "
            f"{len(reads)} operands but carries {len(graph.deps[idx])} "
            "dependency slots (truncated dependency row?)")
    ops = []
    for r, producer in zip(reads, graph.deps[idx]):
        if producer >= 0:
            v = values[producer]
            if v is None:
                raise InterleaveViolation(
                    f"use-before-publish: task #{idx} {task} fetched "
                    f"operand {r} from unpublished producer #{producer} "
                    f"{graph.tasks[producer]}")
            ops.append(v)
        else:
            ops.append(kernels.initial(r))
    return ops


def explore(graph: TaskGraph, kernels, config: SchedConfig, *,
            schedule: str = "random", salt: int = 0) -> RunResult:
    """Run one complete interleaving of `graph` under `schedule`.

    Raises InterleaveViolation on a use-before-publish, double-publish,
    or scheduler deadlock.  Deterministic: the schedule RNG is seeded
    from (config.seed, schedule, salt) only.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; one of {SCHEDULES}")
    keys = priority_keys(graph, config)
    # NB: no hash() here -- str hashing is per-process randomized and would
    # break reproducibility-from-config
    rng = random.Random((config.seed * 0x9E3779B1 + salt) * len(SCHEDULES)
                        + SCHEDULES.index(schedule))
    n = graph.n
    ndeps = graph.indegree()
    ready = [keys[i] for i in range(n) if ndeps[i] == 0]
    heapq.heapify(ready)
    values: list = [None] * n
    done = 0
    dispatch: list[int] = []
    steps: list[tuple[int, str, int]] = []
    workers = [_Worker(w) for w in range(config.workers)]

    def task_key(w: _Worker):
        """Priority key of the task this worker's next step concerns."""
        if w.phase == _POP:
            return ready[0]          # the task a pop would take
        return keys[w.task]

    def runnable() -> list[_Worker]:
        return [w for w in workers
                if w.phase != _POP or (ready and done < n)]

    def pick(cands: list[_Worker]) -> _Worker:
        if schedule == "random":
            return cands[rng.randrange(len(cands))]
        if schedule == "reverse_priority":
            return max(cands, key=lambda w: (task_key(w), w.wid))
        if schedule == "convert_last":
            def is_convert(w):
                idx = ready[0][-1] if w.phase == _POP else w.task
                return graph.tasks[idx].kind == "CONVERT"
            return min(cands, key=lambda w: (is_convert(w), w.wid))
        # starve0: worker 0 advances only as the sole runnable worker
        rest = [w for w in cands if w.wid != 0]
        return min(rest or cands, key=lambda w: w.wid)

    guard = 0
    while done < n:
        cands = runnable()
        if not cands:
            raise InterleaveViolation(
                f"deadlock: {done}/{n} tasks done, ready queue empty, "
                "no worker in flight (cyclic or truncated dependencies)")
        w = pick(cands)
        if w.phase == _POP:
            key = heapq.heappop(ready)
            idx = key[-1] if len(key) > 1 else key[0]
            w.task = idx
            dispatch.append(idx)
            w.ops = _fetch(graph, kernels, values, idx)
            w.phase = _COMPUTE
            steps.append((w.wid, _POP, idx))
        elif w.phase == _COMPUTE:
            out = kernels.run(graph.tasks[w.task], w.ops)
            if hasattr(out, "block_until_ready"):
                out.block_until_ready()
            w.out = out
            w.ops = None
            w.phase = _PUBLISH
            steps.append((w.wid, _COMPUTE, w.task))
        else:
            idx = w.task
            if values[idx] is not None:
                raise InterleaveViolation(
                    f"write-once violation: task #{idx} "
                    f"{graph.tasks[idx]} published twice")
            values[idx] = w.out
            w.out = None
            done += 1
            for s in graph.succs[idx]:
                ndeps[s] -= 1
                if ndeps[s] == 0:
                    heapq.heappush(ready, keys[s])
                elif ndeps[s] < 0:
                    raise InterleaveViolation(
                        f"dependency count of task #{s} went negative "
                        f"(double publish of a producer?)")
            w.phase = _POP
            w.task = -1
            steps.append((w.wid, _PUBLISH, idx))
        guard += 1
        if guard > 3 * n * max(config.workers, 1) + 16:
            raise InterleaveViolation(
                f"stepper did not terminate after {guard} steps "
                f"({done}/{n} tasks done)")

    return RunResult(schedule=schedule, seed=config.seed, salt=salt,
                     workers=config.workers, signature=tuple(steps),
                     dispatch=tuple(dispatch), values=tuple(values))


def replay_inorder(graph: TaskGraph, kernels) -> tuple:
    """Sequential reference: execute the task stream in emission order."""
    values: list = [None] * graph.n
    for idx in range(graph.n):
        out = kernels.run(graph.tasks[idx],
                          _fetch(graph, kernels, values, idx))
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        values[idx] = out
    return tuple(values)


def bitwise_equal(a, b) -> bool:
    na, nb = np.asarray(a), np.asarray(b)
    return na.dtype == nb.dtype and na.shape == nb.shape \
        and na.tobytes() == nb.tobytes()


def values_bitwise_equal(got: tuple, want: tuple) -> list[int]:
    """Indices of tasks whose outputs differ bitwise (empty = equal)."""
    return [i for i, (g, w) in enumerate(zip(got, want))
            if not bitwise_equal(g, w)]


# ---------------------------------------------------------------------------
# the (variant x policy x p) matrix sweep
# ---------------------------------------------------------------------------

#: fast subset: enough concurrency per cell for schedules to diverge, small
#: enough that the CLI gate stays interactive.  The slow pytest `concurrency`
#: marker runs the full matrix (tests/test_concurrency_interleave.py).
FAST_CELLS = (
    ("tile", "full", 3), ("tile", "full", 4),
    ("tile", "mixed", 3), ("tile", "mixed", 4),
    ("tile", "three_tier", 4),
    ("panel", "mixed", 4),
    ("dst", "mixed", 4),
)


def _policies():
    from ...core.precision import PrecisionPolicy
    return {
        "full": PrecisionPolicy.full(),
        "mixed": PrecisionPolicy.tpu(2),
        "three_tier": PrecisionPolicy.three_tier(1, 3),
    }


@dataclasses.dataclass(frozen=True)
class MatrixReport:
    rows: tuple                  # per-(cell, workers) summary dicts
    n_runs: int
    n_distinct: int              # distinct interleaving signatures, summed
    violations: tuple[str, ...]  # stepper invariant failures
    mismatches: tuple[str, ...]  # bitwise differences vs sequential replay

    @property
    def ok(self) -> bool:
        return not self.violations and not self.mismatches

    def render(self) -> str:
        lines = [(f"interleave: {self.n_runs} runs, {self.n_distinct} "
                  f"distinct interleavings, {len(self.violations)} "
                  f"violations, {len(self.mismatches)} bitwise mismatches")]
        for r in self.rows:
            lines.append(
                f"  {r['variant']}/{r['policy']} p={r['p']} W={r['workers']}: "
                f"{r['runs']} runs, {r['distinct']} distinct")
        lines += [f"  VIOLATION: {v}" for v in self.violations]
        lines += [f"  MISMATCH: {m}" for m in self.mismatches]
        return "\n".join(lines)


def run_matrix(cells=FAST_CELLS, *, nb: int = 4, seeds: int = 12,
               workers=(2, 3), priority: str = "critical_path",
               base_seed: int = 1) -> MatrixReport:
    """Explore seeded-random + adversarial schedules over `cells`.

    Per (cell, worker count): every adversarial schedule once plus `seeds`
    seeded-random runs, each checked for stepper invariants and bitwise
    equality with the in-order sequential replay (tile cells additionally
    against `core.tile_cholesky` itself).  Distinctness is counted on the
    full step signature within each (cell, workers) group.
    """
    from ...core.tile_cholesky import assemble_lower, tile_cholesky
    from ...sched.kernels import make_kernels
    from ...verify.generators import spd_matrix

    policies = _policies()
    rows = []
    violations: list[str] = []
    mismatches: list[str] = []
    n_runs = n_distinct = 0

    for variant, plabel, p in cells:
        policy = policies[plabel]
        graph = build_graph(variant, p, policy)
        a = spd_matrix(p * 7 + nb, p * nb, cond=50.0)
        kernels = make_kernels(variant, a, nb, policy)
        reference = replay_inorder(graph, kernels)
        engine = None
        if variant == "tile":
            engine = np.asarray(tile_cholesky(a, nb, policy))
        for nw in workers:
            signatures = set()
            runs_here = 0
            for schedule in SCHEDULES:
                salts = range(seeds) if schedule == "random" else range(1)
                for salt in salts:
                    config = SchedConfig(priority=priority, workers=nw,
                                         backend="sim",
                                         seed=base_seed + salt)
                    label = (f"{variant}/{plabel} p={p} W={nw} "
                             f"{schedule}#{salt}")
                    try:
                        res = explore(graph, kernels, config,
                                      schedule=schedule, salt=salt)
                    except InterleaveViolation as e:
                        violations.append(f"{label}: {e}")
                        continue
                    finally:
                        runs_here += 1
                    signatures.add(res.signature)
                    bad = values_bitwise_equal(res.values, reference)
                    if bad:
                        mismatches.append(
                            f"{label}: tasks {bad[:6]} differ from "
                            "sequential replay")
                    elif engine is not None:
                        store = dict(kernels.initial_store())
                        for idx, task in enumerate(graph.tasks):
                            if task.kind != "CONVERT":
                                store[task.target] = res.values[idx]
                        got = np.asarray(assemble_lower(
                            store, p, nb, policy.hi))
                        if got.tobytes() != engine.tobytes():
                            mismatches.append(
                                f"{label}: assembled factor differs from "
                                "core.tile_cholesky")
            rows.append({"variant": variant, "policy": plabel, "p": p,
                         "workers": nw, "runs": runs_here,
                         "distinct": len(signatures)})
            n_runs += runs_here
            n_distinct += len(signatures)

    return MatrixReport(rows=tuple(rows), n_runs=n_runs,
                        n_distinct=n_distinct,
                        violations=tuple(violations),
                        mismatches=tuple(mismatches))
