"""CLI gate: `python -m repro.analysis --check`.

Runs the analysis layers and exits non-zero on any violation:

  1. the precision-flow linter over src/repro/ (findings must be fixed,
     pragma-suppressed, or baselined with a reason);
  2. the tile-DAG hazard checker over every (variant x policy x p) cell of
     the conformance matrix -- tile/panel/dst at p in {1, 4, 8} under the
     full / mixed / three_tier policies;
  3. with ``--concurrency`` (or ``--concurrency-only``), the concurrency
     soundness layer (DESIGN.md §14): the lock-discipline linter over the
     runtime/recorder sources (findings share the lint baseline), the
     happens-before verifier over freshly emitted p=8 schedules plus a
     Chrome-trace round-trip, and the interleaving model checker's fast
     matrix (>= 200 distinct interleavings, all bitwise-clean).

Stale baseline entries -- entries no active rule reproduces -- FAIL the
check (someone fixed the finding; the suppression must be removed with
it).  ``--allow-stale-baseline`` downgrades that to a note for transition
windows.  Entries belonging to rules of a layer that did not run (e.g.
lockguard rules without ``--concurrency``) are never counted stale.

This is the blocking `static-analysis` CI job (fast path: pure AST + a few
thousand symbolic tasks; only the interleaving checker touches JAX
numerics, on tiny matrices).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .baseline import BASELINE_PATH, load_baseline, split_baselined, update_baseline
from .dag import HazardError, analyze, check_dag
from .lint import RULES as LINT_RULES
from .lint import lint_tree

SRC_ROOT = Path(__file__).resolve().parents[1]   # .../src/repro

DAG_PS = (1, 4, 8)
DAG_VARIANTS = ("tile", "panel", "dst")


def _dag_policies():
    from ..core.precision import PrecisionPolicy
    return {
        "full": PrecisionPolicy.full(),
        "mixed": PrecisionPolicy.tpu(2),
        "three_tier": PrecisionPolicy.three_tier(1, 3),
    }


def run_lint(root: Path, *, update: bool = False, concurrency: bool = False,
             allow_stale: bool = False) -> int:
    from .concurrency.lockguard import LOCKGUARD_RULES, lockguard_files

    findings = lint_tree(root)
    active_rules = set(LINT_RULES)
    if concurrency:
        findings = findings + lockguard_files(SRC_ROOT)
        active_rules |= set(LOCKGUARD_RULES)
    if update:
        n = update_baseline(findings)
        print(f"baseline: wrote {n} entries to {BASELINE_PATH} "
              "(fill in any TODO reasons before committing)")
        return 0
    try:
        entries = load_baseline()
    except ValueError as e:
        print(f"BASELINE ERROR: {e}")
        return 1
    new, old, unused = split_baselined(findings, entries)
    for f in new:
        print(f"LINT: {f.render()}")
    # An unused entry is stale only if its rule actually ran this
    # invocation -- lockguard entries are not stale in a lint-only run.
    stale = [e for e in unused if e["rule"] in active_rules]
    for e in stale:
        print(f"{'note' if allow_stale else 'STALE BASELINE'}: entry no "
              f"finding reproduces (fixed? remove it): "
              f"{e['rule']} {e['path']} {e['code']!r}")
    print(f"lint: {len(findings)} findings "
          f"({len(old)} baselined, {len(new)} new), "
          f"{len(stale)} stale baseline entries over {root}")
    return 1 if new or (stale and not allow_stale) else 0


def run_dag(*, verbose: bool = False, as_json: bool = False) -> int:
    rows, failures = [], 0
    for variant in DAG_VARIANTS:
        for label, policy in _dag_policies().items():
            for p in DAG_PS:
                try:
                    rep = analyze(variant, p, policy, label=label)
                except HazardError as e:
                    print(f"DAG HAZARD: {e}")
                    failures += 1
                    continue
                fr = rep.tier_fractions()
                rows.append({
                    "variant": variant, "policy": label, "p": p,
                    "tasks": rep.n_tasks, "converts": rep.n_converts,
                    "hi_frac": round(fr.get("hi", 0.0), 4),
                    "lo_frac": round(fr.get("lo", 0.0), 4),
                    "lo2_frac": round(fr.get("lo2", 0.0), 4),
                    "critical_path_tasks": rep.critical_path_tasks,
                    "critical_path_flops_nb3": round(
                        rep.critical_path_flops, 3),
                })
    if as_json:
        print(json.dumps(rows, indent=2))
    elif verbose:
        hdr = ("variant", "policy", "p", "tasks", "converts",
               "hi_frac", "lo_frac", "lo2_frac", "critical_path_tasks")
        print(" ".join(f"{h:>12}" for h in hdr))
        for r in rows:
            print(" ".join(f"{r[h]!s:>12}" for h in hdr))
    checked = len(rows) + failures
    print(f"dag: {checked} (variant, policy, p) cells checked, "
          f"{failures} hazard/policy violations")
    return 1 if failures else 0


def run_sched_replay() -> int:
    """Replay dynamic-scheduler dispatch orders through the hazard checker.

    For every matrix cell and every ready-queue priority, run the
    simulated scheduler (pure Python, no numerics) and feed the actual
    dispatch order -- a dependency-respecting permutation of the emission
    order -- back through `check_dag`'s protocol state machine.  An
    out-of-order execution the runtime would perform must itself be
    hazard-free and precision-consistent, worker count notwithstanding.
    """
    from ..sched.config import PRIORITIES, SchedConfig
    from ..sched.runtime import build_graph, simulate

    checked, failures = 0, 0
    for variant in DAG_VARIANTS:
        for label, policy in _dag_policies().items():
            for p in DAG_PS:
                graph = build_graph(variant, p, policy)
                for priority in PRIORITIES:
                    cfg = SchedConfig(priority=priority, workers=4,
                                      backend="sim")
                    rep = simulate(graph, cfg)
                    reordered = [graph.tasks[i] for i in rep.dispatch_order]
                    checked += 1
                    try:
                        check_dag(reordered, p, policy, variant,
                                  label=f"{label}/sched:{priority}")
                    except HazardError as e:
                        print(f"SCHED REPLAY HAZARD: {e}")
                        failures += 1
    print(f"sched-replay: {checked} (variant, policy, p, priority) dispatch "
          f"orders replayed, {failures} hazard violations")
    return 1 if failures else 0


#: HB gate cells: every variant under a representative policy pack, at the
#: conformance sweep's largest p.  dst graphs only exist under a dst policy.
HB_P = 8
HB_PRIORITIES = ("fifo", "critical_path")
HB_SEEDS = (0, 7)

#: floor on distinct interleavings the model checker must explore
INTERLEAVE_DISTINCT_MIN = 200


def _hb_cells():
    from ..core.precision import PrecisionPolicy
    return (
        ("tile", "full", PrecisionPolicy.full()),
        ("tile", "mixed", PrecisionPolicy.tpu(2)),
        ("tile", "three_tier", PrecisionPolicy.three_tier(1, 3)),
        ("panel", "mixed", PrecisionPolicy.tpu(2)),
        ("dst", "dst", PrecisionPolicy.dst(2)),
    )


def run_concurrency(*, verbose: bool = False) -> int:
    """Concurrency soundness gate: HB-verify fresh schedules + one trace
    round-trip, then the interleaving model checker's fast matrix."""
    from ..sched.config import SchedConfig
    from ..sched.runtime import build_graph, simulate
    from ..sched.trace import chrome_trace, validate_trace
    from .concurrency.hb import verify_sched_report, verify_trace
    from .concurrency.interleave import run_matrix

    failures = 0

    # --- happens-before over freshly emitted schedules --------------------
    checked = 0
    for variant, plabel, policy in _hb_cells():
        graph = build_graph(variant, HB_P, policy)
        for priority in HB_PRIORITIES:
            for seed in HB_SEEDS:
                cfg = SchedConfig(priority=priority, workers=4,
                                  backend="sim", seed=seed)
                rep = verify_sched_report(simulate(graph, cfg), graph)
                checked += 1
                if verbose:
                    print(f"  {variant}/{plabel}/{priority}/seed={seed}: "
                          f"{rep.n_events} events, {rep.n_dep_edges} dep + "
                          f"{rep.n_po_edges} po edges, "
                          f"{rep.n_write_pairs} write pairs")
                if not rep.ok:
                    print(f"HB VIOLATION ({variant}/{plabel}/{priority}/"
                          f"seed={seed}):\n{rep.render()}")
                    failures += 1
    # round-trip one cell through the Chrome-trace JSON path the CI
    # artifact check uses (otherData metadata -> graph reconstruction)
    graph = build_graph("tile", HB_P, _hb_cells()[1][2])
    trace = chrome_trace(simulate(graph, SchedConfig(workers=4)))
    validate_trace(trace)
    rep = verify_trace(trace)     # graph rebuilt from otherData
    checked += 1
    if not rep.ok:
        print(f"HB VIOLATION (trace round-trip):\n{rep.render()}")
        failures += 1
    print(f"hb: {checked} recorded schedules verified "
          f"(p={HB_P}, {len(_hb_cells())} cells x priorities x seeds + "
          f"trace round-trip), {failures} with violations")

    # --- interleaving model checker ---------------------------------------
    matrix = run_matrix()
    if verbose or not matrix.ok:
        print(matrix.render())
    else:
        print(f"interleave: {matrix.n_runs} runs, {matrix.n_distinct} "
              f"distinct interleavings, all bitwise-equal to sequential "
              f"replay")
    if not matrix.ok:
        failures += 1
    if matrix.n_distinct < INTERLEAVE_DISTINCT_MIN:
        print(f"INTERLEAVE: only {matrix.n_distinct} distinct interleavings "
              f"explored (< {INTERLEAVE_DISTINCT_MIN}); raise seeds/cells")
        failures += 1
    return 1 if failures else 0


def run_hb_trace(path: Path) -> int:
    """Verify one recorded Chrome trace file (the CI artifact gate)."""
    from .concurrency.hb import HBError, verify_trace_file

    try:
        rep = verify_trace_file(path)
    except (HBError, OSError, ValueError, KeyError) as e:
        print(f"HB TRACE ERROR: {path}: {e}")
        return 1
    print(rep.render())
    return 0 if rep.ok else 1


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Precision-flow linter + tile-DAG hazard checker")
    parser.add_argument("--check", action="store_true",
                        help="run both layers, exit non-zero on violations "
                             "(default action)")
    parser.add_argument("--lint-only", action="store_true")
    parser.add_argument("--dag-only", action="store_true")
    parser.add_argument("--sched-replay-only", action="store_true",
                        help="only replay scheduler dispatch orders through "
                             "the hazard checker")
    parser.add_argument("--concurrency", action="store_true",
                        help="also run the concurrency soundness layer "
                             "(lockguard + happens-before + interleavings)")
    parser.add_argument("--concurrency-only", action="store_true",
                        help="run only the concurrency soundness layer")
    parser.add_argument("--hb-trace", type=Path, metavar="PATH",
                        help="verify one recorded Chrome trace file with the "
                             "happens-before checker and exit")
    parser.add_argument("--root", type=Path, default=SRC_ROOT,
                        help="package root to lint (default: src/repro)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite baseline.json from current findings "
                             "(keeps existing reasons)")
    parser.add_argument("--allow-stale-baseline", action="store_true",
                        help="downgrade stale baseline entries from a "
                             "failure to a note")
    parser.add_argument("--verbose", action="store_true",
                        help="print the per-cell DAG report table")
    parser.add_argument("--json", action="store_true",
                        help="emit the DAG report as JSON")
    args = parser.parse_args(argv)

    if args.hb_trace is not None:
        return run_hb_trace(args.hb_trace)

    rc = 0
    if args.sched_replay_only:
        rc = run_sched_replay()
        if rc == 0:
            print("static analysis: OK")
        return rc
    if args.concurrency_only:
        # lockguard findings gate through the shared lint baseline
        rc = run_lint(args.root, update=args.update_baseline,
                      concurrency=True,
                      allow_stale=args.allow_stale_baseline)
        if not args.update_baseline:
            rc |= run_concurrency(verbose=args.verbose)
        if rc == 0:
            print("static analysis: OK")
        return rc
    if not args.dag_only:
        rc |= run_lint(args.root, update=args.update_baseline,
                       concurrency=args.concurrency,
                       allow_stale=args.allow_stale_baseline)
    if not args.lint_only and not args.update_baseline:
        rc |= run_dag(verbose=args.verbose, as_json=args.json)
        rc |= run_sched_replay()
        if args.concurrency:
            rc |= run_concurrency(verbose=args.verbose)
    if rc == 0:
        print("static analysis: OK")
    return rc
