"""CLI gate: `python -m repro.analysis --check`.

Runs both layers and exits non-zero on any violation:

  1. the precision-flow linter over src/repro/ (findings must be fixed,
     pragma-suppressed, or baselined with a reason);
  2. the tile-DAG hazard checker over every (variant x policy x p) cell of
     the conformance matrix -- tile/panel/dst at p in {1, 4, 8} under the
     full / mixed / three_tier policies.

This is the blocking `static-analysis` CI job (fast path: pure AST + a few
thousand symbolic tasks, no JAX numerics are executed).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .baseline import BASELINE_PATH, load_baseline, split_baselined, update_baseline
from .dag import HazardError, analyze, check_dag
from .lint import lint_tree

SRC_ROOT = Path(__file__).resolve().parents[1]   # .../src/repro

DAG_PS = (1, 4, 8)
DAG_VARIANTS = ("tile", "panel", "dst")


def _dag_policies():
    from ..core.precision import PrecisionPolicy
    return {
        "full": PrecisionPolicy.full(),
        "mixed": PrecisionPolicy.tpu(2),
        "three_tier": PrecisionPolicy.three_tier(1, 3),
    }


def run_lint(root: Path, *, update: bool = False) -> int:
    findings = lint_tree(root)
    if update:
        n = update_baseline(findings)
        print(f"baseline: wrote {n} entries to {BASELINE_PATH} "
              "(fill in any TODO reasons before committing)")
        return 0
    try:
        entries = load_baseline()
    except ValueError as e:
        print(f"BASELINE ERROR: {e}")
        return 1
    new, old, unused = split_baselined(findings, entries)
    for f in new:
        print(f"LINT: {f.render()}")
    if unused:
        for e in unused:
            print(f"note: stale baseline entry (fixed? remove it): "
                  f"{e['rule']} {e['path']} {e['code']!r}")
    print(f"lint: {len(findings)} findings "
          f"({len(old)} baselined, {len(new)} new) over {root}")
    return 1 if new else 0


def run_dag(*, verbose: bool = False, as_json: bool = False) -> int:
    rows, failures = [], 0
    for variant in DAG_VARIANTS:
        for label, policy in _dag_policies().items():
            for p in DAG_PS:
                try:
                    rep = analyze(variant, p, policy, label=label)
                except HazardError as e:
                    print(f"DAG HAZARD: {e}")
                    failures += 1
                    continue
                fr = rep.tier_fractions()
                rows.append({
                    "variant": variant, "policy": label, "p": p,
                    "tasks": rep.n_tasks, "converts": rep.n_converts,
                    "hi_frac": round(fr.get("hi", 0.0), 4),
                    "lo_frac": round(fr.get("lo", 0.0), 4),
                    "lo2_frac": round(fr.get("lo2", 0.0), 4),
                    "critical_path_tasks": rep.critical_path_tasks,
                    "critical_path_flops_nb3": round(
                        rep.critical_path_flops, 3),
                })
    if as_json:
        print(json.dumps(rows, indent=2))
    elif verbose:
        hdr = ("variant", "policy", "p", "tasks", "converts",
               "hi_frac", "lo_frac", "lo2_frac", "critical_path_tasks")
        print(" ".join(f"{h:>12}" for h in hdr))
        for r in rows:
            print(" ".join(f"{r[h]!s:>12}" for h in hdr))
    checked = len(rows) + failures
    print(f"dag: {checked} (variant, policy, p) cells checked, "
          f"{failures} hazard/policy violations")
    return 1 if failures else 0


def run_sched_replay() -> int:
    """Replay dynamic-scheduler dispatch orders through the hazard checker.

    For every matrix cell and every ready-queue priority, run the
    simulated scheduler (pure Python, no numerics) and feed the actual
    dispatch order -- a dependency-respecting permutation of the emission
    order -- back through `check_dag`'s protocol state machine.  An
    out-of-order execution the runtime would perform must itself be
    hazard-free and precision-consistent, worker count notwithstanding.
    """
    from ..sched.config import PRIORITIES, SchedConfig
    from ..sched.runtime import build_graph, simulate

    checked, failures = 0, 0
    for variant in DAG_VARIANTS:
        for label, policy in _dag_policies().items():
            for p in DAG_PS:
                graph = build_graph(variant, p, policy)
                for priority in PRIORITIES:
                    cfg = SchedConfig(priority=priority, workers=4,
                                      backend="sim")
                    rep = simulate(graph, cfg)
                    reordered = [graph.tasks[i] for i in rep.dispatch_order]
                    checked += 1
                    try:
                        check_dag(reordered, p, policy, variant,
                                  label=f"{label}/sched:{priority}")
                    except HazardError as e:
                        print(f"SCHED REPLAY HAZARD: {e}")
                        failures += 1
    print(f"sched-replay: {checked} (variant, policy, p, priority) dispatch "
          f"orders replayed, {failures} hazard violations")
    return 1 if failures else 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Precision-flow linter + tile-DAG hazard checker")
    parser.add_argument("--check", action="store_true",
                        help="run both layers, exit non-zero on violations "
                             "(default action)")
    parser.add_argument("--lint-only", action="store_true")
    parser.add_argument("--dag-only", action="store_true")
    parser.add_argument("--sched-replay-only", action="store_true",
                        help="only replay scheduler dispatch orders through "
                             "the hazard checker")
    parser.add_argument("--root", type=Path, default=SRC_ROOT,
                        help="package root to lint (default: src/repro)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite baseline.json from current findings "
                             "(keeps existing reasons)")
    parser.add_argument("--verbose", action="store_true",
                        help="print the per-cell DAG report table")
    parser.add_argument("--json", action="store_true",
                        help="emit the DAG report as JSON")
    args = parser.parse_args(argv)

    rc = 0
    if args.sched_replay_only:
        rc = run_sched_replay()
        if rc == 0:
            print("static analysis: OK")
        return rc
    if not args.dag_only:
        rc |= run_lint(args.root, update=args.update_baseline)
    if not args.lint_only and not args.update_baseline:
        rc |= run_dag(verbose=args.verbose, as_json=args.json)
        rc |= run_sched_replay()
    if rc == 0:
        print("static analysis: OK")
    return rc
