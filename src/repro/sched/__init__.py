"""Dynamic tile-task runtime: out-of-order Cholesky scheduling (DESIGN.md §12).

The StarPU layer of the reproduction: consumes the symbolic task DAGs
`repro.analysis.dag` extracts from the tile/panel/DST engines and executes
them with a dependency-counting ready-queue scheduler -- a simulated
virtual-time backend for makespan/utilization studies and a real threaded
backend whose per-tile kernels are bitwise-identical to the sequential
engines.  `python -m repro.sched` schedules one cell and writes a Chrome
trace; `core.tile_cholesky(..., schedule=SchedConfig(...))` is the opt-in
engine hook.
"""

from .config import BACKENDS, PRIORITIES, SchedConfig  # noqa: F401
from .runtime import (  # noqa: F401
    SchedReport,
    TaskEvent,
    TaskGraph,
    build_graph,
    downstream_cost,
    execute,
    priority_keys,
    scheduled_cholesky,
    scheduled_tile_cholesky,
    simulate,
    simulate_dag,
)
from .kernels import KernelSet, make_kernels, tier_dtype  # noqa: F401
from .trace import (  # noqa: F401
    chrome_trace,
    format_summary,
    load_and_validate,
    summary_rows,
    validate_trace,
    write_trace,
)
