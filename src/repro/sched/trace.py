"""Observability: Chrome `trace_event` JSON + per-tier/worker summaries.

The runtime records one `TaskEvent` per executed task (begin/end, tier,
worker).  This module turns that into

  * a Chrome trace (the JSON Array-with-metadata format both
    `chrome://tracing` and https://ui.perfetto.dev open directly): one
    complete "X" event per task on its worker's track, tier as the
    category so the UI colors hi/lo/lo2 lanes distinctly;

  * `validate_trace` -- the structural gate the tests and CI run over
    every emitted file: well-formed events, non-negative monotone
    timestamps, and no two tasks overlapping on one worker track;

  * plain-dict summary rows (per tier and per worker) for benchmark
    output and the CLI.
"""

from __future__ import annotations

import json
from typing import TYPE_CHECKING

if TYPE_CHECKING:                      # pragma: no cover - typing only
    from .runtime import SchedReport

_REQUIRED_KEYS = ("name", "ph", "ts", "dur", "pid", "tid")


def worker_names(report: "SchedReport") -> dict[int, str]:
    """Per-worker display name: the real OS thread name when the executor
    recorded one (`TaskEvent.worker_name`), else the legacy worker<N>."""
    names = {w: f"worker{w}" for w in range(report.workers)}
    for ev in report.events:
        if getattr(ev, "worker_name", ""):
            names[ev.worker] = ev.worker_name
    return names


def chrome_trace(report: "SchedReport") -> dict:
    """Render a report as a Chrome trace_event JSON object."""
    names = worker_names(report)
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": f"repro.sched {report.backend} "
                         f"{report.variant}/{report.priority}"},
    }]
    for w in range(report.workers):
        events.append({"name": "thread_name", "ph": "M", "pid": 0, "tid": w,
                       "args": {"name": names[w]}})
    for ev in report.events:
        events.append({
            "name": f"{ev.kind}@k={ev.k}",
            "cat": ev.tier,
            "ph": "X",
            "ts": ev.start,
            "dur": ev.end - ev.start,
            "pid": 0,
            "tid": ev.worker,
            "args": {"task": ev.name, "kind": ev.kind, "tier": ev.tier,
                     "k": ev.k, "index": ev.index,
                     "worker": names[ev.worker]},
        })
    other = {
        "backend": report.backend,
        "variant": report.variant,
        "priority": report.priority,
        "workers": report.workers,
        "n_tasks": report.n_tasks,
        "makespan": report.makespan,
        "utilization": report.utilization,
        "overlap_fraction": report.overlap_fraction,
    }
    # graph identity (PR 10): enough to rebuild the symbolic DAG so the
    # happens-before verifier can check a trace artifact standalone
    if getattr(report, "p", 0):
        other["p"] = report.p
        mode, d1, d2 = report.policy
        other["policy"] = {"mode": mode, "diag_thick": d1, "diag_thick2": d2}
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def write_trace(report: "SchedReport", path) -> None:
    with open(path, "w") as fh:
        json.dump(chrome_trace(report), fh, indent=1)


def validate_trace(trace: dict) -> None:
    """Raise ValueError unless `trace` is a well-formed, overlap-free trace.

    Checks: top-level shape, required keys on every complete event,
    non-negative timestamps/durations, and -- per worker track -- strictly
    monotone, non-overlapping task intervals.  Tracks may be keyed by a
    numeric tid or by a thread-name string (the named variant the real
    executor emits); anything else is malformed.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    # tracks are keyed on (pid, tid): merged traces (repro.obs) put host
    # spans on pid 1 with thread-local tids that may collide numerically
    # with pid-0 worker tids -- those are different tracks, not overlaps.
    per_track: dict[tuple, list[tuple[float, float, str]]] = {}
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"malformed event {ev!r}")
        if ev["ph"] != "X":
            continue
        for key in _REQUIRED_KEYS:
            if key not in ev:
                raise ValueError(f"event missing {key!r}: {ev!r}")
        ts, dur = ev["ts"], ev["dur"]
        if not (isinstance(ts, (int, float)) and ts >= 0):
            raise ValueError(f"non-finite/negative ts in {ev!r}")
        if not (isinstance(dur, (int, float)) and dur >= 0):
            raise ValueError(f"non-finite/negative dur in {ev!r}")
        if not isinstance(ev["tid"], (int, str)) or isinstance(ev["tid"], bool):
            raise ValueError(f"tid must be an int or a thread-name string, "
                             f"got {ev['tid']!r} in {ev!r}")
        per_track.setdefault((ev["pid"], ev["tid"]), []).append(
            (ts, ts + dur, str(ev["name"])))
    if not per_track:
        raise ValueError("trace has no complete ('X') events")
    for (pid, tid), spans in per_track.items():
        spans.sort()
        for (s0, e0, n0), (s1, _, n1) in zip(spans, spans[1:]):
            if s1 < e0:
                raise ValueError(
                    f"track pid={pid} tid={tid}: {n0!r} [{s0}, {e0}) "
                    f"overlaps {n1!r} starting at {s1}")


def load_and_validate(path) -> dict:
    with open(path) as fh:
        trace = json.load(fh)
    validate_trace(trace)
    return trace


def summary_rows(report: "SchedReport") -> list[dict]:
    """Per-tier and per-worker aggregate rows for tables/benchmarks."""
    rows: list[dict] = []
    by_tier: dict[str, list] = {}
    for ev in report.events:
        by_tier.setdefault(ev.tier, []).append(ev)
    for tier in sorted(by_tier):
        evs = by_tier[tier]
        rows.append({"scope": "tier", "name": tier, "tasks": len(evs),
                     "busy": sum(e.end - e.start for e in evs)})
    names = worker_names(report)
    for w, busy in enumerate(report.worker_busy):
        n = sum(1 for e in report.events if e.worker == w)
        util = busy / report.makespan if report.makespan > 0 else 1.0
        idle = max(report.makespan - busy, 0.0)
        rows.append({"scope": "worker", "name": names[w], "tasks": n,
                     "busy": busy, "util": util, "idle": idle,
                     "idle_frac": 1.0 - util})
    return rows


def format_summary(report: "SchedReport") -> str:
    lines = [
        f"{report.backend} {report.variant} priority={report.priority} "
        f"W={report.workers}: {report.n_tasks} tasks, "
        f"makespan={report.makespan:.3f}, "
        f"utilization={report.utilization:.3f}, "
        f"overlap={report.overlap_fraction:.3f}",
    ]
    for row in summary_rows(report):
        if row["scope"] == "tier":
            lines.append(f"  tier {row['name']:>4}: {row['tasks']:>5} tasks, "
                         f"busy {row['busy']:.3f}")
        else:
            lines.append(f"  {row['name']}: {row['tasks']:>5} tasks, "
                         f"busy {row['busy']:.3f}, util {row['util']:.3f}, "
                         f"idle {row['idle']:.3f} ({row['idle_frac']:.1%})")
    return "\n".join(lines)
