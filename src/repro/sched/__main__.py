"""CLI: schedule one (variant, policy, p) cell and optionally emit a trace.

    python -m repro.sched --variant tile --policy mixed --p 8 \
        --workers 4 --priority critical_path --trace sched-trace.json

Defaults to the simulated backend (no numerics), which is what CI uses to
produce the uploaded trace artifact; `--backend real` runs the threaded
executor on a synthetic SPD problem of n = p * nb.
"""

from __future__ import annotations

import argparse
import sys

from .config import PRIORITIES, SchedConfig
from .runtime import scheduled_tile_cholesky, simulate_dag
from .trace import format_summary, load_and_validate


def _policies():
    from ..core.precision import PrecisionPolicy
    return {
        "full": PrecisionPolicy.full(),
        "mixed": PrecisionPolicy.tpu(2),
        "three_tier": PrecisionPolicy.three_tier(1, 3),
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.sched",
        description="Dynamic tile-Cholesky scheduler: run one cell, "
                    "print the summary, optionally write a Chrome trace")
    parser.add_argument("--variant", default="tile",
                        choices=("tile", "panel", "dst"))
    parser.add_argument("--policy", default="mixed",
                        choices=sorted(_policies()))
    parser.add_argument("--p", type=int, default=8, help="tile-grid size")
    parser.add_argument("--nb", type=int, default=16,
                        help="tile edge (real backend problem size = p*nb)")
    parser.add_argument("--workers", type=int, default=4)
    parser.add_argument("--priority", default="critical_path",
                        choices=PRIORITIES)
    parser.add_argument("--backend", default="sim", choices=("sim", "real"))
    parser.add_argument("--trace", default=None, metavar="PATH",
                        help="write (and validate) Chrome trace JSON here; "
                             "open in chrome://tracing or ui.perfetto.dev")
    args = parser.parse_args(argv)

    policy = _policies()[args.policy]
    config = SchedConfig(priority=args.priority, workers=args.workers,
                         backend=args.backend, trace_path=args.trace)
    if args.backend == "sim":
        report = simulate_dag(args.variant, args.p, policy, config)
    else:
        from repro.verify.generators import spd_matrix

        if args.variant != "tile":
            print("real backend CLI supports --variant tile", file=sys.stderr)
            return 2
        a = spd_matrix(0, args.p * args.nb, cond=100.0)
        _, report = scheduled_tile_cholesky(a, args.nb, policy, config)
    print(format_summary(report))
    if args.trace:
        load_and_validate(args.trace)
        print(f"trace: wrote + validated {args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
