"""Per-tile kernels for the real executor -- engine math, task-sized.

Each `KernelSet` maps one symbolic task (`repro.analysis.dag.Task`) plus
its operand arrays to one output array, using exactly the arithmetic the
corresponding sequential engine performs on that tile:

  tile  -- `core/tile_cholesky.py` line for line: `_potrf`,
           `_trsm_right_lt`, hi SYRK/GEMM via plain matmul, lo GEMM via
           `lo_matmul`, CONVERTs via `astype` on policy dtypes;
  panel -- `core/panel_cholesky.py` per tile: batch-of-1
           `_batched_trsm_right_lt` (the batched triangular-solve path
           rounds differently from the unbatched one, and a slice of a
           batch is bitwise a batch of one -- pinned in the equivalence
           tests), per-slice einsum updates, per-tile `lo_matmul` blocks
           of the big off-band GEMM;
  dst   -- the tile-level dense right-looking hi path per super-block.

Because every kernel consumes the same operand values and applies the
same op in the same order per tile, a dependency-respecting execution of
the task stream reproduces the engine's tile values bitwise -- that is
the property `tests/test_sched_equivalence.py` gates on the full
(variant x policy x p) matrix.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..analysis.dag import HI, LO, LO2, Task, storage_tier
from ..core.precision import PrecisionPolicy, lo_matmul
from ..core.tile_cholesky import _potrf, _trsm_right_lt, split_tiles
from ..core.panel_cholesky import _batched_trsm_right_lt


def tier_dtype(policy: PrecisionPolicy, sym: str):
    """Map a symbolic tier (hi/lo/lo2) to the policy's storage dtype."""
    return {HI: policy.hi, LO: policy.lo, LO2: policy.lo2}[sym]


class KernelSet:
    """Initial tile storage + one-task execution for one engine variant."""

    variant: str

    def __init__(self, a, nb: int, policy: PrecisionPolicy):
        self.policy = policy
        self.nb = nb
        tiles, self.p = split_tiles(a, nb)
        self._store = {}
        for (i, j), t in tiles.items():
            sym = storage_tier(policy, i, j, variant=self.variant)
            if sym is None:          # dropped (DST off-block) tile
                continue
            self._store[(i, j)] = t.astype(tier_dtype(policy, sym))

    def initial_store(self) -> dict:
        return self._store

    def initial(self, tile: tuple[int, int]):
        return self._store[tile]

    def _out_dtype(self, task: Task):
        return tier_dtype(self.policy,
                          storage_tier(self.policy, *task.target,
                                       variant=self.variant))

    def run(self, task: Task, ops: list):
        raise NotImplementedError


class TileKernels(KernelSet):
    """`tile_cholesky`'s Algorithm 1 tile ops (see module docstring)."""

    variant = "tile"

    def run(self, task: Task, ops: list):
        pol = self.policy
        hi, lo = pol.hi, pol.lo
        if task.kind == "POTRF":
            return _potrf(ops[0], hi)                     # line 8 dpotrf
        if task.kind == "CONVERT":                        # dlag2s / sconv2d
            return ops[0].astype(tier_dtype(pol, task.tier))
        if task.kind == "TRSM":
            l_kk, a_ik = ops
            if task.tier == HI:                           # line 12 dtrsm
                return _trsm_right_lt(l_kk, a_ik, hi, hi)
            return _trsm_right_lt(l_kk, a_ik,             # line 14 strsm
                                  pol.solve_dtype, self._out_dtype(task))
        if task.kind == "SYRK":                           # line 19 dsyrk
            c, acc = ops
            return acc - c @ jnp.swapaxes(c, -1, -2)
        a_ik, a_jk, acc = ops                             # GEMM
        if task.tier == HI:                               # line 25 dgemm
            return acc - a_ik @ jnp.swapaxes(a_jk, -1, -2)
        upd = lo_matmul(a_ik, jnp.swapaxes(a_jk, -1, -2), pol, tier=lo)
        return (acc - upd).astype(self._out_dtype(task))  # line 27 sgemm


class PanelKernels(KernelSet):
    """`panel_cholesky_banded`'s per-step ops, sliced to single tiles."""

    variant = "panel"

    def run(self, task: Task, ops: list):
        pol = self.policy
        hi = pol.hi
        lo = pol.lo if pol.mode != "full" else pol.hi   # single-tier off
        if task.kind == "POTRF":
            return jnp.linalg.cholesky(ops[0])
        if task.kind == "CONVERT":
            dst = hi if task.tier == HI else lo
            return ops[0].astype(dst)
        if task.kind == "TRSM":
            l_kk, a_ik = ops
            if task.tier == HI:                           # dtrsm on the band
                return _batched_trsm_right_lt(l_kk, a_ik[None], hi, hi)[0]
            return _batched_trsm_right_lt(                # batched strsm
                l_kk, a_ik[None], pol.solve_dtype, lo)[0]
        if task.kind in ("SYRK", "GEMM") and task.tier == HI:
            lhs, rhs, acc = ops                           # dsyrk / dgemm
            upd = jnp.einsum("ab,cb->ac", lhs, rhs, preferred_element_type=hi)
            return acc - upd.astype(hi)
        lhs, rhs, acc = ops                               # off-band sgemm
        upd = lo_matmul(lhs, jnp.swapaxes(rhs, -1, -2), pol)
        return acc - upd.astype(lo)


class DstKernels(KernelSet):
    """Dense right-looking hi tile ops inside each DST super-block."""

    variant = "dst"

    def run(self, task: Task, ops: list):
        hi = self.policy.hi
        if task.kind == "POTRF":
            return _potrf(ops[0], hi)
        if task.kind == "TRSM":
            l_kk, a_ik = ops
            return _trsm_right_lt(l_kk, a_ik, hi, hi)
        if task.kind == "SYRK":
            c, acc = ops
            return acc - c @ jnp.swapaxes(c, -1, -2)
        a_ik, a_jk, acc = ops
        return acc - a_ik @ jnp.swapaxes(a_jk, -1, -2)


_KERNELS = {"tile": TileKernels, "panel": PanelKernels, "dst": DstKernels}


def make_kernels(variant: str, a, nb: int, policy: PrecisionPolicy) -> KernelSet:
    return _KERNELS[variant](a, nb, policy)
