"""Scheduler configuration -- validated the way `PrecisionPolicy` is.

A `SchedConfig` fully determines a schedule given a task DAG: the priority
policy orders the ready queue, `workers` sets the (virtual or OS-thread)
worker pool, and the cost knobs feed the simulated backend's virtual
clock.  Everything is validated eagerly in ``__post_init__`` so a typo'd
policy name fails at construction, not three layers down inside a worker
thread.
"""

from __future__ import annotations

import dataclasses

from ..launch.costmodel import CONVERT_COST_UNITS

#: ready-queue priority policies (DESIGN.md §12):
#:   fifo          -- emission order, the sequential engines' order
#:   panel_first   -- right-looking lookahead: factor panel k+1 before
#:                    draining step k's trailing updates (StarPU's
#:                    priority hint in ExaGeoStat)
#:   critical_path -- longest downstream weighted path first
PRIORITIES = ("fifo", "panel_first", "critical_path")

BACKENDS = ("sim", "real")


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    priority: str = "critical_path"   # one of PRIORITIES
    workers: int = 4                  # worker pool size W (>= 1)
    backend: str = "real"             # "real" threads | "sim" virtual time
    convert_cost: float = CONVERT_COST_UNITS  # sim CONVERT duration (units)
    trace_path: str | None = None     # write Chrome trace JSON here if set
    calibrated: bool = False          # price tasks with the measured
                                      # launch/calibration.json table
                                      # (python -m repro.obs calibrate)
                                      # instead of analytic MXU weights
    seed: int = 0                     # deterministic tie-breaking seed:
                                      # 0 = emission-order ties (the
                                      # historical order); any other value
                                      # permutes equal-priority ties with a
                                      # seeded shuffle, and the interleaving
                                      # explorer (analysis.concurrency)
                                      # derives its schedule RNG from it --
                                      # a run is reproducible from the
                                      # config alone

    def __post_init__(self):
        if not isinstance(self.seed, int) or isinstance(self.seed, bool) \
                or self.seed < 0:
            raise ValueError(
                f"seed must be a non-negative int, got {self.seed!r}")
        if not isinstance(self.calibrated, bool):
            raise ValueError(
                f"calibrated must be a bool, got {self.calibrated!r}")
        if self.priority not in PRIORITIES:
            raise ValueError(
                f"unknown scheduler priority {self.priority!r}; "
                f"expected one of {PRIORITIES}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown scheduler backend {self.backend!r}; "
                f"expected one of {BACKENDS}")
        if not isinstance(self.workers, int) or self.workers < 1:
            raise ValueError(f"workers must be an int >= 1, got {self.workers!r}")
        if not (self.convert_cost >= 0.0):   # also rejects NaN
            raise ValueError(
                f"convert_cost must be >= 0, got {self.convert_cost!r}")
