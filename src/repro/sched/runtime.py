"""StarPU-style dynamic tile-task runtime (DESIGN.md §12).

The static layer (`repro.analysis.dag`) already extracts each engine's
POTRF/TRSM/SYRK/GEMM/CONVERT task stream and proves it hazard-free; this
module is the runtime that *executes* that stream out of order, the way
StarPU executes ExaGeoStat's tile Cholesky (paper §4): a dependency-
counting ready queue, a pluggable priority policy, and two executor
backends behind one interface --

  * `simulate`  -- virtual-time list scheduling: every task advances a
    deterministic clock by its `launch.costmodel.task_virtual_cost`
    duration (per-tier MXU FLOP weights + a conversion/data-movement
    term).  Reports makespan, per-worker utilization, and overlap for W
    workers without touching a single float of numerics.

  * `execute`   -- a real threaded executor: W OS threads pop ready tile
    tasks and run per-tile NumPy/JAX kernels (`sched.kernels`, the same
    `_potrf`/`_trsm_right_lt`/SYRK-update math as `core/tile_cholesky`).
    Results are bitwise-identical to the sequential engines: every task
    output is an immutable value keyed by producer index, so any
    dependency-respecting pop order computes exactly the same bits.

Both backends record per-task begin/end/tier/worker events (`TaskEvent`)
consumed by `sched.trace` for Chrome `trace_event` JSON and summary
tables, and both log their dispatch order, which CI replays through
`check_dag` -- the executed order must itself be hazard-free.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
import threading
import time

from ..analysis.dag import (
    Task,
    build_dag,
    successor_map,
    task_dependencies,
)
from ..launch.costmodel import task_virtual_cost
from .. import obs
from .config import SchedConfig

_KIND_RANK = {"POTRF": 0, "CONVERT": 1, "TRSM": 2, "SYRK": 3, "GEMM": 4}


# ---------------------------------------------------------------------------
# task graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskGraph:
    """A task stream plus its dependency structure, ready to schedule."""
    variant: str
    p: int
    policy: object                     # PrecisionPolicy
    tasks: tuple[Task, ...]
    deps: tuple[tuple[int, ...], ...]  # per-task producer indices
    succs: tuple[tuple[int, ...], ...]

    @property
    def n(self) -> int:
        return len(self.tasks)

    def indegree(self) -> list[int]:
        return [len({d for d in row if d >= 0}) for row in self.deps]


def build_graph(variant: str, p: int, policy) -> TaskGraph:
    tasks = build_dag(variant, p, policy)
    deps = task_dependencies(tasks, p, policy, variant)
    succs = successor_map(deps)
    return TaskGraph(variant=variant, p=p, policy=policy,
                     tasks=tuple(tasks),
                     deps=tuple(tuple(d) for d in deps),
                     succs=tuple(tuple(s) for s in succs))


def downstream_cost(graph: TaskGraph, config: SchedConfig) -> list[float]:
    """Per-task critical-path-to-exit length under the virtual cost model.

    The same longest-chain computation `DagReport` runs forward over
    producers, run backward over consumers: a task's priority is its own
    cost plus the heaviest chain hanging off it.
    """
    costs = [task_virtual_cost(t, convert_cost=config.convert_cost,
                               calibrated=config.calibrated)
             for t in graph.tasks]
    down = [0.0] * graph.n
    for idx in range(graph.n - 1, -1, -1):   # emission order is topological
        down[idx] = costs[idx] + max((down[s] for s in graph.succs[idx]),
                                     default=0.0)
    return down


def _tie_order(graph: TaskGraph, config: SchedConfig) -> list[int]:
    """Per-task tie-break rank: emission order, or a seeded permutation.

    `config.seed == 0` keeps the historical behavior (ties pop in emission
    order).  Any other seed shuffles the rank deterministically, so runs
    that differ only in equal-priority tie-breaking are reproducible from
    the config alone -- the knob the interleaving explorer
    (`analysis.concurrency.interleave`) turns to diversify schedules.
    """
    if config.seed == 0:
        return list(range(graph.n))
    order = list(range(graph.n))
    random.Random(config.seed).shuffle(order)
    rank = [0] * graph.n
    for r, idx in enumerate(order):
        rank[idx] = r
    return rank


def priority_keys(graph: TaskGraph, config: SchedConfig) -> list[tuple]:
    """Total-order ready-queue key per task (smaller pops first)."""
    if config.priority == "fifo":
        # fifo IS the emission order -- there are no ties for a seed to break
        return [(idx,) for idx in range(graph.n)]
    tie = _tie_order(graph, config)
    if config.priority == "panel_first":
        # right-looking lookahead: later panels outrank earlier trailing
        # updates, and within a step the factor ops outrank the updates
        return [(t.k, _KIND_RANK[t.kind], tie[idx], idx)
                for idx, t in enumerate(graph.tasks)]
    down = downstream_cost(graph, config)
    return [(-down[idx], tie[idx], idx) for idx in range(graph.n)]


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskEvent:
    """One executed task: who ran it, when, and what it was."""
    index: int
    name: str
    kind: str
    tier: str
    k: int
    worker: int
    start: float       # sim: virtual units; real: microseconds since t0
    end: float
    worker_name: str = ""   # real backend: the OS thread's name; sim: sim-w<N>


def policy_desc(policy) -> tuple:
    """(mode, diag_thick, diag_thick2) -- enough to rebuild the symbolic
    task graph (storage tiers ignore dtypes), carried through trace files
    so `analysis.concurrency.hb` can verify an artifact standalone."""
    return (policy.mode, int(policy.diag_thick), int(policy.diag_thick2))


@dataclasses.dataclass(frozen=True)
class SchedReport:
    backend: str
    variant: str
    priority: str
    workers: int
    n_tasks: int
    makespan: float
    worker_busy: tuple[float, ...]
    dispatch_order: tuple[int, ...]
    events: tuple[TaskEvent, ...]
    p: int = 0                         # tile-grid size (0 = unknown/legacy)
    policy: tuple = ()                 # policy_desc(...) of the graph's policy

    @property
    def utilization(self) -> float:
        denom = self.workers * self.makespan
        return sum(self.worker_busy) / denom if denom > 0 else 1.0

    @property
    def overlap_fraction(self) -> float:
        """Fraction of the makespan during which >= 2 workers are busy."""
        if self.makespan <= 0:
            return 0.0
        bounds = []
        for ev in self.events:
            bounds.append((ev.start, 1))
            bounds.append((ev.end, -1))
        bounds.sort()
        busy, last_t, overlapped = 0, 0.0, 0.0
        for t, delta in bounds:
            if busy >= 2:
                overlapped += t - last_t
            busy += delta
            last_t = t
        return overlapped / self.makespan


# ---------------------------------------------------------------------------
# simulated backend: deterministic virtual-time list scheduling
# ---------------------------------------------------------------------------

def simulate(graph: TaskGraph, config: SchedConfig) -> SchedReport:
    """Schedule `graph` on W virtual workers; no numerics, no wall clock.

    Deterministic by construction: ties break on (priority key, task
    index) in the ready heap and (finish time, worker id) in the event
    heap, and task durations come from the cost model (analytic weights,
    or the measured calibration table when `config.calibrated`) -- the
    same config always yields the same makespan, bit for bit.
    """
    with obs.span("sched.simulate", variant=graph.variant, p=graph.p,
                  workers=config.workers, priority=config.priority,
                  calibrated=config.calibrated):
        return _simulate(graph, config)


def _simulate(graph: TaskGraph, config: SchedConfig) -> SchedReport:
    keys = priority_keys(graph, config)
    costs = [task_virtual_cost(t, convert_cost=config.convert_cost,
                               calibrated=config.calibrated)
             for t in graph.tasks]
    ndeps = graph.indegree()
    ready = [keys[i] for i in range(graph.n) if ndeps[i] == 0]
    heapq.heapify(ready)
    idle = list(range(config.workers))
    heapq.heapify(idle)
    running: list[tuple[float, int, int]] = []   # (end, worker, task)
    busy = [0.0] * config.workers
    dispatch: list[int] = []
    events: list[TaskEvent] = []
    t, done = 0.0, 0

    while done < graph.n:
        while ready and idle:
            key = heapq.heappop(ready)
            idx = key[-1] if len(key) > 1 else key[0]
            w = heapq.heappop(idle)
            end = t + costs[idx]
            heapq.heappush(running, (end, w, idx))
            dispatch.append(idx)
            task = graph.tasks[idx]
            events.append(TaskEvent(
                index=idx, name=str(task), kind=task.kind, tier=task.tier,
                k=task.k, worker=w, start=t, end=end,
                worker_name=f"sim-w{w}"))
            busy[w] += costs[idx]
        if not running:
            raise RuntimeError("scheduler deadlock: no ready task and no "
                               "running task (cyclic or truncated DAG)")
        end, w, idx = heapq.heappop(running)
        t = end
        heapq.heappush(idle, w)
        done += 1
        for s in graph.succs[idx]:
            ndeps[s] -= 1
            if ndeps[s] == 0:
                heapq.heappush(ready, keys[s])

    return SchedReport(
        backend="sim", variant=graph.variant, priority=config.priority,
        workers=config.workers, n_tasks=graph.n, makespan=t,
        worker_busy=tuple(busy), dispatch_order=tuple(dispatch),
        events=tuple(events), p=graph.p, policy=policy_desc(graph.policy))


# ---------------------------------------------------------------------------
# real backend: threaded out-of-order execution of per-tile kernels
# ---------------------------------------------------------------------------

class _ExecState:
    """Shared mutable state behind one lock; values are write-once.

    The ``# repro: guarded-by=cond`` annotations below are machine-checked
    by `analysis.concurrency.lockguard`: any mutation of an annotated
    attribute outside a ``with <state>.cond:`` block is a lint finding.
    `graph` and `keys` are immutable after construction and deliberately
    unannotated.
    """

    def __init__(self, graph: TaskGraph, keys: list[tuple]):
        self.graph = graph
        self.keys = keys
        self.ndeps = graph.indegree()                 # repro: guarded-by=cond
        self.ready = [keys[i] for i in range(graph.n) if self.ndeps[i] == 0]  # repro: guarded-by=cond
        heapq.heapify(self.ready)
        self.values: list = [None] * graph.n          # repro: guarded-by=cond
        self.done = 0                                 # repro: guarded-by=cond
        self.dispatch: list[int] = []                 # repro: guarded-by=cond
        self.events: list[TaskEvent] = []             # repro: guarded-by=cond
        self.error: BaseException | None = None       # repro: guarded-by=cond
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)


def execute(graph: TaskGraph, config: SchedConfig, kernels) -> tuple[dict, SchedReport]:
    """Run the DAG on `config.workers` OS threads with real tile kernels.

    `kernels` is a `sched.kernels.KernelSet`: it owns the initial tile
    storage and maps one task + its operand arrays to one output array.
    Every output is stored write-once under its task index, and every
    consumer fetches operands by producer index (`graph.deps`), so a late
    reader can never observe a newer tile version -- out-of-order
    execution is bitwise-equal to in-order execution by construction.

    Returns (final tile store, report).  The final store maps each tile
    to its last writer's output (its factored value).
    """
    keys = priority_keys(graph, config)
    state = _ExecState(graph, keys)
    n = graph.n
    t0 = time.perf_counter()
    telemetry = obs.enabled()
    if telemetry:
        # anchor for obs.export.merged_chrome_trace: host spans and the
        # scheduler's per-task events share this perf_counter origin
        obs.gauge("sched.t0", t0)

    def fetch(idx: int) -> list:
        task = graph.tasks[idx]
        reads = task.reads if task.kind != "CONVERT" else (task.target,)
        ops = []
        for r, producer in zip(reads, graph.deps[idx]):
            ops.append(state.values[producer] if producer >= 0
                       else kernels.initial(r))
        return ops

    def worker(w: int) -> None:
        while True:
            with state.cond:
                while not state.ready:
                    if state.done >= n or state.error is not None:
                        return
                    state.cond.wait()
                key = heapq.heappop(state.ready)
                idx = key[-1] if len(key) > 1 else key[0]
                state.dispatch.append(idx)
                ops = fetch(idx)
            task = graph.tasks[idx]
            start = time.perf_counter()
            try:
                out = kernels.run(task, ops)
                # materialize before publishing so a consumer never races
                # an async dispatch
                out.block_until_ready()
            except BaseException as e:          # propagate to the caller
                with state.cond:
                    if state.error is None:
                        state.error = e
                    state.cond.notify_all()
                return
            end = time.perf_counter()
            if telemetry:
                # per-(kind, tier) wall times -- the per-task profile the
                # calibrator's summary and the Prometheus exposition report
                obs.observe(f"sched.task.{task.kind}.{task.tier}",
                            end - start)
                obs.inc(f"sched.tasks.{task.kind}")
            with state.cond:
                state.values[idx] = out
                state.done += 1
                state.events.append(TaskEvent(
                    index=idx, name=str(task), kind=task.kind,
                    tier=task.tier, k=task.k, worker=w,
                    start=(start - t0) * 1e6, end=(end - t0) * 1e6,
                    worker_name=threading.current_thread().name))
                for s in graph.succs[idx]:
                    state.ndeps[s] -= 1
                    if state.ndeps[s] == 0:
                        heapq.heappush(state.ready, keys[s])
                state.cond.notify_all()

    threads = [threading.Thread(target=worker, args=(w,), daemon=True,
                                name=f"sched-w{w}")
               for w in range(config.workers)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if state.error is not None:
        raise state.error

    store = dict(kernels.initial_store())
    for idx, task in enumerate(graph.tasks):
        if task.kind != "CONVERT":
            store[task.target] = state.values[idx]

    makespan = max((ev.end for ev in state.events), default=0.0)
    busy = [0.0] * config.workers
    for ev in state.events:
        busy[ev.worker] += ev.end - ev.start
    report = SchedReport(
        backend="real", variant=graph.variant, priority=config.priority,
        workers=config.workers, n_tasks=n, makespan=makespan,
        worker_busy=tuple(busy), dispatch_order=tuple(state.dispatch),
        events=tuple(state.events), p=graph.p,
        policy=policy_desc(graph.policy))
    return store, report


# ---------------------------------------------------------------------------
# high-level entry points
# ---------------------------------------------------------------------------

def _maybe_trace(report: SchedReport, config: SchedConfig) -> None:
    if config.trace_path:
        from .trace import write_trace
        write_trace(report, config.trace_path)


def simulate_dag(variant: str, p: int, policy,
                 config: SchedConfig | None = None) -> SchedReport:
    """Build + schedule one engine's DAG on the virtual backend."""
    config = config or SchedConfig(backend="sim")
    report = simulate(build_graph(variant, p, policy), config)
    _maybe_trace(report, config)
    return report


def scheduled_cholesky(a, nb: int, policy, config: SchedConfig, *,
                       variant: str = "tile"):
    """Factor SPD `a` by executing the variant's task DAG out of order.

    Real-backend entry point behind `core.tile_cholesky(..., schedule=)`.
    Returns (tile store, report); tile values are bitwise-identical to the
    sequential engine's internal store for the same variant and policy.
    """
    from .kernels import make_kernels

    if config.backend != "real":
        raise ValueError("scheduled_cholesky needs backend='real'; use "
                         "simulate_dag for the virtual backend")
    n = a.shape[-1]
    assert n % nb == 0, f"n={n} must be a multiple of nb={nb}"
    p = n // nb
    graph = build_graph(variant, p, policy)
    kernels = make_kernels(variant, a, nb, policy)
    with obs.span("sched.execute", variant=variant, p=p,
                  workers=config.workers, priority=config.priority):
        store, report = execute(graph, config, kernels)
    _maybe_trace(report, config)
    return store, report


def scheduled_tile_cholesky(a, nb: int, policy, config: SchedConfig):
    """Drop-in `tile_cholesky`: same result assembled in hi, via the runtime."""
    from ..core.tile_cholesky import assemble_lower

    store, report = scheduled_cholesky(a, nb, policy, config, variant="tile")
    p = a.shape[-1] // nb
    return assemble_lower(store, p, nb, policy.hi), report
