"""Golden accuracy artifacts: drift fails CI loudly instead of silently.

The committed file `golden/accuracy.json` records the conformance sweep's
measured metrics on the reference machine.  The gate compares a fresh sweep
against it with a slack factor (default 2x) plus per-metric absolute
floors, so

  * genuine accuracy regressions (a kernel edit that doubles factor error)
    fail CI even while still inside the registry's ~30x envelope, and
  * BLAS/compiler reassociation noise across machines does not flake.

Update flow (after an INTENDED numerical change):

    PYTHONPATH=src python -m repro.verify.golden --update
    # or: pytest tests/test_conformance_sweep.py --update-golden

then commit the regenerated JSON together with the change that moved the
numbers -- the diff is the reviewable accuracy impact.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

GOLDEN_PATH = Path(__file__).parent / "golden" / "accuracy.json"

# Comparison slack: fresh metric must stay below max(golden * SLACK, floor).
SLACK = 2.0
FLOORS = {
    "factor_rel": 1e-6,
    "backward_rel": 1e-6,
    "loglik_drift": 1e-6,
    "pmse_rel": 1e-4,
    "max_rel": 1e-6,
    "max_abs": 1e-5,
}
_METRICS = tuple(FLOORS)


def _metric_view(record: dict) -> dict:
    return {k: float(record[k]) for k in _METRICS if k in record}


def save_golden(records, path: Path = None) -> Path:
    """Write the sweep's metrics as the new golden artifact."""
    path = GOLDEN_PATH if path is None else Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "format": 1,
        "slack": SLACK,
        "records": {r["id"]: _metric_view(r) for r in records},
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return path


def load_golden(path: Path = None) -> dict:
    path = GOLDEN_PATH if path is None else Path(path)
    return json.loads(path.read_text())


def compare_to_golden(records, golden: dict = None, *,
                      slack: float = SLACK) -> list[tuple[str, str]]:
    """(record id, message) for every drift vs the golden artifact.

    Flags three failure classes: a metric exceeding its golden value by
    more than `slack` (accuracy regression), a sweep record missing from
    the golden file (gate doesn't cover it -- regenerate), and a golden
    record missing from the sweep (coverage silently lost).
    """
    golden = load_golden() if golden is None else golden
    gold_records = golden["records"]
    drifts = []
    seen = set()
    for rec in records:
        rid = rec["id"]
        seen.add(rid)
        gold = gold_records.get(rid)
        if gold is None:
            drifts.append((rid, "not in golden file -- run --update-golden"))
            continue
        for name, value in _metric_view(rec).items():
            if name not in gold:
                drifts.append((rid, f"metric {name} not in golden file"))
                continue
            limit = max(gold[name] * slack, FLOORS[name])
            if value > limit:
                drifts.append((rid, f"{name}={value:.3e} drifted past "
                                    f"golden {gold[name]:.3e} (limit "
                                    f"{limit:.3e})"))
    for rid in gold_records:
        if rid not in seen:
            drifts.append((rid, "golden record missing from sweep -- "
                                "coverage lost"))
    return drifts


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="Golden accuracy gate for the conformance sweep.")
    parser.add_argument("--update", action="store_true",
                        help="run the sweep and rewrite the golden file")
    parser.add_argument("--check", action="store_true",
                        help="run the sweep and fail on drift (default)")
    parser.add_argument("--path", default=None,
                        help="override the golden file location")
    args = parser.parse_args(argv)

    from .bounds import lookup_bound  # noqa: F401  (import check)
    from .conformance import check_records, run_conformance

    records = run_conformance()
    violations = check_records(records)
    for rid, msg in violations:
        print(f"BOUND  {rid}: {msg}", file=sys.stderr)

    if args.update:
        path = save_golden(records, args.path)
        print(f"wrote {len(records)} golden records to {path}")
        return 1 if violations else 0

    golden = load_golden(args.path)
    drifts = compare_to_golden(records, golden)
    for rid, msg in drifts:
        print(f"DRIFT  {rid}: {msg}", file=sys.stderr)
    ok = not violations and not drifts
    print(f"{len(records)} records, {len(violations)} bound violations, "
          f"{len(drifts)} golden drifts")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
