"""fp64 reference answers and error metrics for the verification stack.

Oracle convention: every oracle upcasts the SAME fp32 input matrix the
mixed-precision path factors (rather than rebuilding the covariance in
fp64), so the measured error isolates the factorization/solve chain from
covariance-build rounding.  All oracle arithmetic runs under
`jax.experimental.enable_x64()` and all metrics are computed in fp64.

Metrics (the quantities the tolerance registry bounds):

  rel_frobenius(l, l_ref)   forward factor error ||L - L_ref||_F / ||L_ref||_F
  backward_error(l, a)      reconstruction error ||L L^T - A||_F / ||A||_F
  loglik_drift(ll, ll_ref)  |ll - ll_ref| / max(1, |ll_ref|)
  pmse_drift(p, p_ref)      |pmse - pmse_ref| / pmse_ref
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# fp64 reference answers
# ---------------------------------------------------------------------------


def exact_factor(cov) -> np.ndarray:
    """fp64 dense lower Cholesky of (the upcast of) `cov`."""
    with jax.experimental.enable_x64():
        a = jnp.asarray(np.asarray(cov, np.float64))
        l = jnp.linalg.cholesky(a)
        return np.asarray(l, np.float64)


def exact_loglik(cov, z) -> float:
    """Exact Gaussian log-likelihood (paper Eq. 2) in fp64."""
    a = np.asarray(cov, np.float64)
    zz = np.asarray(z, np.float64)
    l = exact_factor(a)
    n = zz.shape[-1]
    w = np.linalg.solve(l, zz)  # triangular; np.linalg.solve is exact enough
    return float(-0.5 * n * np.log(2.0 * np.pi)
                 - np.sum(np.log(np.diag(l))) - 0.5 * np.sum(w * w))


def exact_kriging_pmse(cov_oo, z_obs, sigma_no, y_true) -> float:
    """Exact kriging PMSE in fp64, independent of the policy machinery.

    cov_oo: (n, n) observed-observed covariance (jitter included);
    sigma_no: (m, n) cross covariance; y_true: (m,) held-out truth.
    """
    a = np.asarray(cov_oo, np.float64)
    z = np.asarray(z_obs, np.float64)
    c = np.asarray(sigma_no, np.float64)
    y = np.asarray(y_true, np.float64)
    mu = c @ np.linalg.solve(a, z)
    return float(np.mean((mu - y) ** 2))


# ---------------------------------------------------------------------------
# error metrics
# ---------------------------------------------------------------------------


def rel_frobenius(a, ref) -> float:
    """Relative Frobenius distance ||a - ref||_F / ||ref||_F in fp64."""
    a64 = np.asarray(a, np.float64)
    r64 = np.asarray(ref, np.float64)
    denom = np.linalg.norm(r64)
    return float(np.linalg.norm(a64 - r64) / max(denom, np.finfo(np.float64).tiny))


def backward_error(l, a) -> float:
    """Reconstruction (backward) error ||L L^T - A||_F / ||A||_F in fp64."""
    l64 = np.asarray(l, np.float64)
    return rel_frobenius(l64 @ l64.T, np.asarray(a, np.float64))


def loglik_drift(ll, ll_ref) -> float:
    """Log-likelihood drift, normalized so it reads like a relative error
    but stays meaningful when ll_ref crosses zero."""
    ll = float(ll)
    ll_ref = float(ll_ref)
    return abs(ll - ll_ref) / max(1.0, abs(ll_ref))


def pmse_drift(p, p_ref) -> float:
    """Relative PMSE drift vs the fp64 exact predictor."""
    return abs(float(p) - float(p_ref)) / max(float(p_ref),
                                              np.finfo(np.float64).tiny)
