"""Accuracy-oracle verification subsystem.

Turns the paper's central accuracy claim -- band-limited mixed precision
accelerates the tile Cholesky "without any deterioration of the numerical
accuracy" of likelihood evaluation and kriging -- into executable,
regression-gated checks (DESIGN.md §7):

  generators.py   SPD / Matern covariance problem generators with controlled
                  condition number, correlation strength (the paper's
                  weak/medium/strong θ settings) and curve ordering, shared
                  by tests, the conformance sweep and benchmarks.
  oracles.py      fp64 reference answers (factor, log-likelihood, kriging
                  PMSE) plus forward/backward error metrics.
  bounds.py       tolerance registry keyed by (policy mode, dtype pair,
                  diag_thick, conditioning regime) -- the paper's
                  Table-style accuracy envelopes, with a documented
                  tightening procedure.
  conformance.py  the sweep: every kernel pair (kernels/*/ops.py vs ref.py)
                  and the three Cholesky variants (tile / panel / dst)
                  through the generators, checked against the registry.
  golden.py       committed golden accuracy artifacts + the --update-golden
                  flow, so accuracy drift fails CI loudly.
"""

from .generators import (
    CHOLESKY_NB,
    CONDITIONS,
    REGIMES,
    SIZES,
    CholeskyProblem,
    attention_problem,
    cholesky_problems,
    matern_problem,
    spd_matrix,
)
from .oracles import (
    backward_error,
    exact_factor,
    exact_kriging_pmse,
    exact_loglik,
    loglik_drift,
    pmse_drift,
    rel_frobenius,
)
from .bounds import (
    AccuracyBound,
    dtype_pair,
    lookup_bound,
    policy_bound,
    registry_table,
)
from .conformance import (
    check_records,
    default_policies,
    run_conformance,
    sweep_cholesky,
    sweep_kernels,
    sweep_kriging,
)
from .golden import (
    GOLDEN_PATH,
    compare_to_golden,
    load_golden,
    save_golden,
)

__all__ = [
    "CHOLESKY_NB", "CONDITIONS", "REGIMES", "SIZES",
    "CholeskyProblem", "attention_problem", "cholesky_problems",
    "matern_problem", "spd_matrix",
    "backward_error", "exact_factor", "exact_kriging_pmse", "exact_loglik",
    "loglik_drift", "pmse_drift", "rel_frobenius",
    "AccuracyBound", "dtype_pair", "lookup_bound", "policy_bound",
    "registry_table",
    "check_records", "default_policies", "run_conformance", "sweep_cholesky",
    "sweep_kernels", "sweep_kriging",
    "GOLDEN_PATH", "compare_to_golden", "load_golden", "save_golden",
]
