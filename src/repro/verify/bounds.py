"""Tolerance registry: the paper's accuracy envelopes as executable bounds.

Every conformance record is checked against an `AccuracyBound` looked up by
the most specific matching key, in order:

  (mode, pair, diag_thick, regime)
  (mode, pair, regime)
  (mode, pair)
  (mode,)

`pair` is the dtype-pair label from `dtype_pair(policy)` -- e.g.
"f32/bf16" for the TPU production pair, "f64/f32" for the paper's literal
CPU pair, "f32/bf16/f8e4m3" for the three-tier future-work policy.
`regime` is the conditioning regime ("weak"/"medium"/"strong" correlation
for covariance problems; "well"/"moderate"/"ill" for synthetic-SPD
spectra).

How the numbers were set, and how to tighten them
-------------------------------------------------
Each bound is the observed sweep metric (see golden/accuracy.json for the
measured values) rounded UP to one significant digit and then multiplied
by ~3x headroom, so the registry encodes the paper's qualitative envelope
("mixed tracks full to low-precision rounding; DST deteriorates by orders
of magnitude") while absorbing BLAS/compiler reassociation noise across
machines.  To tighten:

  1. run `python -m repro.verify.golden --update` on the reference machine
     and inspect the refreshed measured metrics;
  2. lower the registry entry toward `measured * 3`;
  3. run the accuracy suite (`pytest -m accuracy`) on every supported
     backend -- a bound is only as tight as the loosest backend allows;
  4. commit the registry change together with the regenerated golden file,
     so the gate's two layers (absolute envelope here, drift detection in
     golden.py) move in lockstep.

The golden gate is intentionally much tighter than this registry (factor
~2 vs ~30): the registry answers "is the paper's claim still true", the
golden file answers "did anything move at all".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

from ..core.precision import PrecisionPolicy

_DTYPE_NAMES = {
    "float64": "f64",
    "float32": "f32",
    "bfloat16": "bf16",
    "float8_e4m3fn": "f8e4m3",
}


def _dname(dt) -> str:
    name = jnp.dtype(dt).name
    return _DTYPE_NAMES.get(name, name)


def dtype_pair(policy: PrecisionPolicy) -> str:
    """Stable dtype-pair label for a policy ("f32/bf16", "f64/f32", ...)."""
    if policy.mode == "full":
        return _dname(policy.hi)
    if policy.mode == "dst":
        return f"{_dname(policy.hi)}/zero"
    parts = [_dname(policy.hi), _dname(policy.lo)]
    if policy.mode == "three_tier":
        parts.append(_dname(policy.lo2))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class AccuracyBound:
    """Upper bounds on the sweep metrics; None = metric not bounded here."""
    factor_rel: Optional[float] = None    # ||L - L64||_F / ||L64||_F
    backward_rel: Optional[float] = None  # ||L L^T - A||_F / ||A||_F
    loglik_drift: Optional[float] = None  # |ll - ll64| / max(1, |ll64|)
    pmse_rel: Optional[float] = None      # |pmse - pmse64| / pmse64
    max_rel: Optional[float] = None       # kernel pairs: max relative error
    max_abs: Optional[float] = None       # kernel pairs: max absolute error

    def violations(self, record: dict) -> list[str]:
        """Metric names in `record` that exceed this bound.

        A non-finite metric is always a violation (NaN compares False
        against any limit, so it must be caught explicitly -- a NaN factor
        is the loudest possible accuracy failure, not a pass).
        """
        out = []
        for f in dataclasses.fields(self):
            limit = getattr(self, f.name)
            value = record.get(f.name)
            if limit is None or value is None:
                continue
            if not math.isfinite(value):
                out.append(f"{f.name}={value} is non-finite")
            elif value > limit:
                out.append(f"{f.name}={value:.3e} > bound {limit:.3e}")
        return out


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
# Cholesky-variant envelopes.  The paper's claim under test: mixed-precision
# factor error vs the DP(100%) reference stays at the low-precision rounding
# scale (NO deterioration of loglik/kriging accuracy), while the DST
# baseline at equal band width deteriorates by orders of magnitude.
_REGISTRY: dict[tuple, AccuracyBound] = {
    # -- full fp32 (DP(100%) run through the tile engine or dense LAPACK) --
    # measured (SIZES x REGIMES): factor <= 5e-7, backward <= 6e-8,
    # loglik <= 1e-7, pmse <= 5e-7
    ("full", "f32"): AccuracyBound(
        factor_rel=1e-5, backward_rel=1e-6, loglik_drift=1e-5, pmse_rel=1e-4),

    # -- paper's literal CPU pair: fp64 band, fp32 off-band ---------------
    # measured: factor <= 2.1e-7, backward <= 2.8e-8, loglik <= 6e-8 --
    # the paper's "no deterioration" claim at fp64 reference scale
    ("mixed", "f64/f32"): AccuracyBound(
        factor_rel=1e-5, backward_rel=1e-6, loglik_drift=1e-6),

    # -- degenerate mixed pair f32/f32 (tile engine == full, fp32 noise) --
    ("mixed", "f32/f32"): AccuracyBound(
        factor_rel=1e-5, backward_rel=1e-6, loglik_drift=1e-5, pmse_rel=1e-4),

    # -- TPU production pair: fp32 band, bf16 off-band --------------------
    # bf16 keeps ~3 decimal digits; off-band tiles carry ~1e-2 relative
    # error which the band's hi-precision SYRK keeps from amplifying.
    # measured: factor <= 1.4e-2 (t=1, strong), backward <= 1.8e-3,
    # loglik <= 9.5e-4, pmse <= 1.9e-3
    ("mixed", "f32/bf16"): AccuracyBound(
        factor_rel=5e-2, backward_rel=1e-2, loglik_drift=5e-3, pmse_rel=1e-2),
    # weak correlation barely exercises the off-band -> much tighter
    # measured: factor <= 3.2e-4, backward <= 4.1e-4, loglik <= 1.1e-5
    ("mixed", "f32/bf16", "weak"): AccuracyBound(
        factor_rel=2e-3, backward_rel=2e-3, loglik_drift=1e-4, pmse_rel=1e-3),

    # -- three-tier future work: fp32 / bf16 / fp8(e4m3) ------------------
    # measured at (t=1, t2=3): factor <= 8.9e-2, backward <= 2.4e-2,
    # loglik <= 1.5e-2.  fp8 at t2=2 NaNs on strong correlation (see
    # conformance.default_policies) -- the bound also catches non-finites.
    ("three_tier", "f32/bf16/f8e4m3"): AccuracyBound(
        factor_rel=3e-1, backward_rel=1e-1, loglik_drift=1e-1, pmse_rel=5e-1),

    # -- DST tapering baseline: off-band ZEROED ---------------------------
    # Deterioration is the point: the factor differs from the dense one at
    # O(1) (measured factor up to 0.64); the bound only asserts
    # finiteness-scale sanity, and the claim test asserts DST >> mixed.
    ("dst",): AccuracyBound(
        factor_rel=2.0, backward_rel=1.0, loglik_drift=1.0, pmse_rel=10.0),

    # -- kernel conformance pairs (ops.py vs ref.py) ----------------------
    ("kernel", "matern_cov"): AccuracyBound(max_rel=5e-3, max_abs=1e-3),
    ("kernel", "mp_syrk"): AccuracyBound(max_rel=1e-3, max_abs=1e-2),
    # no max_abs: the ill-conditioned spectrum scales entries to ~1e6, so
    # only scale-relative and backward error are meaningful
    ("kernel", "blocked_potrf"): AccuracyBound(max_rel=1e-3,
                                               backward_rel=1e-4),
    ("kernel", "mp_attention"): AccuracyBound(max_abs=1e-3),
}


def registry_table() -> dict[tuple, AccuracyBound]:
    """Read-only view of the registry (for docs/benchmark reporting)."""
    return dict(_REGISTRY)


def lookup_bound(mode: str, pair: str = None, diag_thick: int = None,
                 regime: str = None) -> AccuracyBound:
    """Most-specific registry entry for the given key components."""
    for key in ((mode, pair, diag_thick, regime),
                (mode, pair, regime),
                (mode, pair),
                (mode,)):
        hit = _REGISTRY.get(key)
        if hit is not None:
            return hit
    raise KeyError(f"no registered bound for mode={mode!r} pair={pair!r} "
                   f"diag_thick={diag_thick!r} regime={regime!r}")


def policy_bound(policy: PrecisionPolicy, regime: str = None) -> AccuracyBound:
    """Registry lookup straight from a policy instance."""
    return lookup_bound(policy.mode, dtype_pair(policy),
                        policy.diag_thick, regime)
