"""Problem generators for the accuracy-oracle subsystem.

One place produces every matrix the verification stack consumes, so tests,
the conformance sweep and the accuracy benchmarks all measure error on the
SAME distributions:

  * `spd_matrix`        -- random SPD with an exact log-spaced spectrum
                           (condition number is a parameter, not an accident);
  * `matern_problem`    -- a synthetic geostatistical problem at one of the
                           paper's correlation strengths (weak/medium/strong
                           θ settings, Sec. VIII-D1), curve-ordered, with the
                           fp32 covariance the mixed-precision paths factor;
  * `cholesky_problems` -- the canonical sweep grid: ≥3 sizes × 3
                           conditioning regimes.

Correlation strength doubles as the conditioning regime for covariance
problems: a longer range (strong θ2) pushes off-diagonal mass toward 1 and
the smallest eigenvalue toward the jitter floor, exactly the regime where
low-precision off-band tiles are most dangerous.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..covariance import CORRELATION_LEVELS, make_dataset
from ..core.likelihood import build_covariance

# Canonical sweep grid (kept small enough for tier-1 eager dispatch: the
# tile engine unrolls p^3/6 tile ops, so p = n/nb stays <= 6).
SIZES = (64, 128, 192)
REGIMES = ("weak", "medium", "strong")
CHOLESKY_NB = 32

# Explicit condition numbers for the synthetic-SPD generators (kernel
# conformance; covariance problems get their conditioning from REGIMES).
CONDITIONS = {"well": 1e2, "moderate": 1e4, "ill": 1e6}


def spd_matrix(seed, n: int, *, cond: float = 100.0, dtype=jnp.float32):
    """Random SPD matrix with eigenvalues log-spaced on [1, cond].

    seed may be an int or a PRNGKey.  The spectrum is exact (Q Λ Q^T with
    orthonormal Q), so `cond` is the true 2-norm condition number -- the
    knob the tolerance registry keys on.
    """
    key = seed if hasattr(seed, "dtype") else jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, n), jnp.float32)
    q, _ = jnp.linalg.qr(a)
    eigs = jnp.logspace(0.0, jnp.log10(cond), n, dtype=jnp.float32)
    return ((q * eigs) @ q.T).astype(dtype)


class CholeskyProblem(NamedTuple):
    """One conditioned covariance-factorization problem.

    `cov` is the fp32 matrix (jitter included) that every factorization
    variant under test receives; oracles upcast THIS matrix to fp64, so
    forward/backward error measures the factorization alone, not the
    covariance build.
    """
    name: str           # e.g. "n128_medium"
    n: int
    nb: int
    regime: str         # "weak" | "medium" | "strong"
    theta: jnp.ndarray  # (3,) generating parameters
    locs: jnp.ndarray   # (n, 2) Morton-ordered locations
    z: jnp.ndarray      # (n,) field draw
    cov: jnp.ndarray    # (n, n) fp32 covariance incl. jitter

    @property
    def p(self) -> int:
        return self.n // self.nb


# Per-regime jitter: identical for all variants of one problem so error
# comparisons are apples-to-apples.
_JITTER = 1e-6


def matern_problem(n: int, regime: str, *, nb: int = CHOLESKY_NB,
                   seed: int = 0, jitter: float = _JITTER) -> CholeskyProblem:
    """One synthetic problem at a paper correlation level, Morton ordered."""
    if regime not in CORRELATION_LEVELS:
        raise ValueError(f"unknown regime {regime!r}; "
                         f"expected one of {sorted(CORRELATION_LEVELS)}")
    theta = CORRELATION_LEVELS[regime]
    # one deterministic key per (n, regime, seed) so golden metrics are stable
    key = jax.random.PRNGKey(
        seed * 7919 + n * 31 + REGIMES.index(regime))
    ds = make_dataset(key, n, theta, nu_static=0.5, ordering="morton")
    cov = build_covariance(ds.locs, theta, nu_static=0.5, jitter=jitter,
                           dtype=jnp.float32)
    return CholeskyProblem(name=f"n{n}_{regime}", n=n, nb=nb, regime=regime,
                           theta=theta, locs=ds.locs, z=ds.z, cov=cov)


def cholesky_problems(sizes=SIZES, regimes=REGIMES, *, nb: int = CHOLESKY_NB,
                      seed: int = 0) -> list[CholeskyProblem]:
    """The canonical ≥3 sizes × 3 conditioning-regimes sweep grid."""
    return [matern_problem(n, r, nb=nb, seed=seed)
            for n in sizes for r in regimes]


def attention_problem(seed: int, b: int, g: int, d: int, sn: int, sf: int,
                      *, scale: float = 1.0, dtype=jnp.float32):
    """Inputs for the banded-precision decode-attention kernel pair.

    `scale` multiplies Q: larger logits sharpen the softmax, the attention
    analogue of conditioning (quantization error concentrates on fewer
    tokens).
    """
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = scale * jax.random.normal(ks[0], (b, g, d), dtype)
    k_near = jax.random.normal(ks[1], (b, sn, d), dtype)
    v_near = jax.random.normal(ks[2], (b, sn, d), dtype)
    k_far = jax.random.normal(ks[3], (b, sf, d), dtype)
    v_far = jax.random.normal(ks[4], (b, sf, d), dtype)
    return q, k_near, v_near, k_far, v_far
