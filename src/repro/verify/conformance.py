"""Kernel + Cholesky-variant conformance sweep against the fp64 oracles.

Every record is a flat dict (JSON-serializable) with an `id`, the registry
key components, and the measured metrics, so the same sweep output feeds

  * the bound check (`check_records` -> tests/test_conformance_sweep.py),
  * the golden regression gate (golden.py), and
  * the accuracy columns in benchmarks (benchmarks/bench_accuracy.py).

Coverage (acceptance floor: >= 3 problem sizes x 3 conditioning regimes):

  sweep_cholesky   tile_cholesky under every registered policy mode, the
                   banded panel_cholesky performance path, and the
                   dst_cholesky tapering baseline, on the canonical
                   SIZES x REGIMES grid of Matern problems.
  sweep_kernels    all four Pallas kernel pairs (matern_cov, mp_gemm's
                   mp_syrk, blocked_potrf, mp_attention) ops.py vs ref.py,
                   each across >= 3 shapes x 3 conditioning knobs.
  sweep_kriging    held-out kriging PMSE vs the fp64 exact predictor for
                   the full and mixed policies on every grid problem.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.likelihood import dst_loglik, loglik_from_factor
from ..core.panel_cholesky import (
    assemble_from_banded,
    banded_loglik,
    build_banded_covariance,
    panel_cholesky_banded,
)
from ..core.precision import PrecisionPolicy
from ..core.tile_cholesky import dst_assemble, dst_cholesky, tile_cholesky
from ..core.kriging import krige_pmse
from ..covariance.matern import matern_covariance
from .bounds import dtype_pair, lookup_bound
from .generators import (
    CONDITIONS,
    CholeskyProblem,
    attention_problem,
    cholesky_problems,
    spd_matrix,
)
from .oracles import (
    backward_error,
    exact_factor,
    exact_kriging_pmse,
    exact_loglik,
    loglik_drift,
    pmse_drift,
    rel_frobenius,
)

# The policy set under test: one entry per paper variant (plus the bf16 and
# three-tier beyond-paper policies).  diag_thick=2 on the p in {2, 4, 6}
# grid covers the degenerate band >= p case at n=64 and genuinely banded
# factorizations at n >= 128.
#
# three_tier uses diag_thick2=3, not 2: fp8(e4m3) tiles one sub-diagonal
# off the band quantize O(1) correlation mass coarsely enough to make the
# strongly-correlated n=192 problem indefinite (NaN factor).  The sweep
# pins the widest-known-good setting; the NaN cliff is a measured property
# of the fp8 far field, recorded here so nobody "fixes" it by loosening a
# bound.
def default_policies() -> dict[str, PrecisionPolicy]:
    return {
        "full_f32": PrecisionPolicy.full(jnp.float32),
        "mixed_f32f32_t2": PrecisionPolicy(mode="mixed", hi=jnp.float32,
                                           lo=jnp.float32, diag_thick=2),
        "mixed_f32bf16_t1": PrecisionPolicy.tpu(diag_thick=1),
        "mixed_f32bf16_t2": PrecisionPolicy.tpu(diag_thick=2),
        "three_tier_t1_t3": PrecisionPolicy.three_tier(diag_thick=1,
                                                       diag_thick2=3),
    }


_DST_THICK = 2


def _chol_record(rid: str, prob: CholeskyProblem, policy_mode: str,
                 pair: str, diag_thick, l, ll) -> dict:
    l_ref = exact_factor(prob.cov)
    ll_ref = exact_loglik(prob.cov, prob.z)
    return {
        "id": rid,
        "kind": "cholesky",
        "mode": policy_mode,
        "pair": pair,
        "diag_thick": diag_thick,
        "regime": prob.regime,
        "n": prob.n,
        "factor_rel": rel_frobenius(l, l_ref),
        "backward_rel": backward_error(l, prob.cov),
        "loglik_drift": loglik_drift(ll, ll_ref),
    }


def sweep_cholesky(problems=None, policies=None, *,
                   paper_pair: bool = True) -> list[dict]:
    """tile / panel / dst variants x the policy set x the problem grid."""
    import jax

    problems = cholesky_problems() if problems is None else problems
    policies = default_policies() if policies is None else policies
    records = []
    for prob in problems:
        # --- faithful tile engine, every policy ---------------------------
        for label, pol in policies.items():
            rid = f"chol/tile/{label}/{prob.name}"
            with obs.span("verify.cell", id=rid, kind="cholesky"):
                l = tile_cholesky(prob.cov.astype(pol.hi), prob.nb, pol)
                ll = float(loglik_from_factor(l, prob.z))
            records.append(_chol_record(
                rid, prob, pol.mode, dtype_pair(pol), pol.diag_thick,
                np.asarray(l, np.float64), ll))

        # --- the paper's literal CPU pair (fp64 band / fp32 off-band) ----
        if paper_pair:
            rid = f"chol/tile/paper_f64f32_t2/{prob.name}"
            with obs.span("verify.cell", id=rid, kind="cholesky"):
                with jax.experimental.enable_x64():
                    pol = PrecisionPolicy.paper_cpu(diag_thick=2)
                    cov64 = jnp.asarray(np.asarray(prob.cov, np.float64))
                    l = tile_cholesky(cov64, prob.nb, pol)
                    ll = float(loglik_from_factor(l, prob.z))
            records.append(_chol_record(
                rid, prob, pol.mode, dtype_pair(pol), pol.diag_thick,
                np.asarray(l, np.float64), ll))

        # --- banded panel performance path (production mixed pair) -------
        rid = f"chol/panel/mixed_f32bf16_t2/{prob.name}"
        with obs.span("verify.cell", id=rid, kind="cholesky"):
            pol = policies.get("mixed_f32bf16_t2") or PrecisionPolicy.tpu(2)
            band, off = build_banded_covariance(
                prob.locs, prob.theta, nb=prob.nb, policy=pol, nu_static=0.5,
                jitter=1e-6)
            t = min(pol.diag_thick, prob.p)
            band, off = panel_cholesky_banded(band, off, pol)
            l_panel = assemble_from_banded(band, off, t)
            ll_panel = float(banded_loglik(band, off, prob.z, t))
        records.append(_chol_record(
            rid, prob, pol.mode, dtype_pair(pol), pol.diag_thick,
            np.asarray(l_panel, np.float64), ll_panel))

        # --- DST tapering baseline ---------------------------------------
        rid = f"chol/dst/t{_DST_THICK}/{prob.name}"
        with obs.span("verify.cell", id=rid, kind="cholesky"):
            blocks = dst_cholesky(prob.cov, prob.nb, diag_thick=_DST_THICK)
            l_dst = dst_assemble(blocks, prob.n)
            ll_dst = float(dst_loglik(blocks, prob.z))
        dst_pol = PrecisionPolicy.dst(_DST_THICK)
        records.append(_chol_record(
            rid, prob, "dst", dtype_pair(dst_pol), _DST_THICK,
            np.asarray(l_dst, np.float64), ll_dst))
    return records


def sweep_kriging(problems=None, policies=None) -> list[dict]:
    """Held-out kriging PMSE drift vs the fp64 exact predictor."""
    from ..core.likelihood import build_covariance

    problems = cholesky_problems() if problems is None else problems
    if policies is None:
        pols = default_policies()
        policies = {k: pols[k] for k in ("full_f32", "mixed_f32bf16_t2")}
    records = []
    for prob in problems:
        n_new = prob.nb                       # hold out one tile row
        n_obs = prob.n - n_new
        locs_o, locs_n = prob.locs[:n_obs], prob.locs[n_obs:]
        z_o, y = prob.z[:n_obs], prob.z[n_obs:]
        cov_oo = build_covariance(locs_o, prob.theta, nu_static=0.5,
                                  jitter=1e-6, dtype=jnp.float32)
        sigma_no = matern_covariance(locs_n, locs_o, prob.theta,
                                     nu_static=0.5)
        ref = exact_kriging_pmse(cov_oo, z_o, sigma_no, y)
        for label, pol in policies.items():
            with obs.span("verify.cell", id=f"krige/{label}/{prob.name}",
                          kind="kriging"):
                score = float(krige_pmse(locs_o, z_o, locs_n, y, prob.theta,
                                         pol, nb=prob.nb, nu_static=0.5,
                                         jitter=1e-6))
            records.append({
                "id": f"krige/{label}/{prob.name}",
                "kind": "kriging",
                "mode": pol.mode,
                "pair": dtype_pair(pol),
                "diag_thick": pol.diag_thick,
                "regime": prob.regime,
                "n": prob.n,
                "pmse_rel": pmse_drift(score, ref),
            })
    return records


# ---------------------------------------------------------------------------
# kernel pairs (ops.py vs ref.py)
# ---------------------------------------------------------------------------


def _scale_rel(out, ref) -> float:
    """max |out - ref| normalized by the reference magnitude scale."""
    out = np.asarray(out, np.float64)
    ref = np.asarray(ref, np.float64)
    return float(np.max(np.abs(out - ref)) / max(np.max(np.abs(ref)), 1e-30))


def _kernel_record(rid, kernel, out, ref, **extra) -> dict:
    rec = {
        "id": rid,
        "kind": "kernel",
        "kernel": kernel,
        "max_rel": _scale_rel(out, ref),
        "max_abs": float(np.max(np.abs(np.asarray(out, np.float64)
                                       - np.asarray(ref, np.float64)))),
    }
    rec.update(extra)
    return rec


def sweep_kernels() -> list[dict]:
    """All four Pallas kernel pairs, each on >= 3 shapes x 3 regimes."""
    import jax

    from ..covariance import random_locations
    from ..kernels.blocked_potrf.ops import potrf
    from ..kernels.blocked_potrf.ref import potrf_ref
    from ..kernels.matern_cov.ops import matern_cov
    from ..kernels.matern_cov.ref import matern_cov_ref
    from ..kernels.mp_attention.ops import banded_decode_attention, quantize_kv
    from ..kernels.mp_attention.ref import banded_decode_attention_ref
    from ..kernels.mp_gemm.ops import mp_syrk
    from ..kernels.mp_gemm.ref import mp_syrk_ref

    records = []

    # matern_cov: 3 tile shapes x 3 smoothness regimes
    for m, n, bm, bn in ((64, 64, 32, 32), (128, 64, 64, 64),
                         (128, 128, 64, 64)):
        la = random_locations(jax.random.PRNGKey(11), m)
        lb = random_locations(jax.random.PRNGKey(12), n)
        for nu in (0.5, 1.5, 2.5):
            rid = f"kern/matern_cov/m{m}n{n}_nu{nu}"
            with obs.span("verify.cell", id=rid, kind="kernel"):
                theta = jnp.array([1.3, 0.12, nu])
                out = matern_cov(la, lb, theta, nu=nu, bm=bm, bn=bn)
                ref = matern_cov_ref(la, lb, theta, nu=nu)
            records.append(_kernel_record(rid, "matern_cov", out, ref))

    # mp_syrk: 3 shapes x 3 band widths (band width = precision regime)
    for m, k, bm, bk in ((128, 64, 64, 64), (256, 128, 64, 64),
                         (256, 64, 128, 64)):
        p = jax.random.normal(jax.random.PRNGKey(13), (m, k), jnp.float32)
        for band in (1, 2, 4):
            rid = f"kern/mp_syrk/m{m}k{k}_band{band}"
            with obs.span("verify.cell", id=rid, kind="kernel"):
                out = mp_syrk(p, band_blocks=band, bm=bm, bk=bk)
                ref = mp_syrk_ref(p, band_blocks=band, bm=bm, bk=bk)
            records.append(_kernel_record(rid, "mp_syrk", out, ref))

    # blocked_potrf: 3 sizes x 3 condition numbers
    for n in (32, 64, 128):
        for cname, cond in CONDITIONS.items():
            rid = f"kern/blocked_potrf/n{n}_{cname}"
            with obs.span("verify.cell", id=rid, kind="kernel"):
                a = spd_matrix(17 + n, n, cond=cond)
                out = potrf(a)
                ref = potrf_ref(a)
            records.append(_kernel_record(
                rid, "blocked_potrf", out, ref,
                backward_rel=backward_error(out, a)))

    # mp_attention: 3 cache shapes x 3 logit scales (softmax sharpness)
    for i, (b, g, d, sn, sf, blk) in enumerate(
            ((2, 4, 64, 128, 256, 128), (1, 8, 128, 256, 128, 64),
             (4, 1, 64, 128, 128, 128))):
        for scale in (0.5, 1.0, 2.0):
            rid = f"kern/mp_attention/shape{i}_scale{scale}"
            with obs.span("verify.cell", id=rid, kind="kernel"):
                q, kn, vn, kf, vf = attention_problem(
                    21 + i, b, g, d, sn, sf, scale=scale)
                kq, vq, scales = quantize_kv(kf, vf, blk=blk)
                near_len = jnp.full((b,), sn, jnp.int32)
                far_len = jnp.full((b,), sf, jnp.int32)
                sm = 1.0 / float(np.sqrt(d))
                out = banded_decode_attention(q, kn, vn, near_len, kq, vq,
                                              scales, far_len, blk=blk,
                                              sm_scale=sm)
                ref = banded_decode_attention_ref(q, kn, vn, near_len, kq, vq,
                                                  scales, far_len, blk=blk,
                                                  sm_scale=sm)
            rec = _kernel_record(rid, "mp_attention", out, ref)
            rec.pop("max_rel")  # softmax outputs are O(1); abs is the metric
            records.append(rec)
    return records


def run_conformance(*, problems=None, policies=None,
                    kernels: bool = True) -> list[dict]:
    """The full sweep: cholesky variants + kriging + kernel pairs."""
    records = sweep_cholesky(problems, policies)
    records += sweep_kriging(problems)
    if kernels:
        records += sweep_kernels()
    return records


def check_records(records) -> list[tuple[str, str]]:
    """(record id, violation message) for every metric out of bounds."""
    violations = []
    for rec in records:
        if rec["kind"] == "kernel":
            bound = lookup_bound("kernel", rec["kernel"])
        else:
            bound = lookup_bound(rec["mode"], rec["pair"],
                                 rec.get("diag_thick"), rec.get("regime"))
        for msg in bound.violations(rec):
            violations.append((rec["id"], msg))
    return violations
