"""Precision policies for the mixed-precision tile Cholesky (paper Sec. VI).

The paper's policy: tiles with tile-index distance |i - j| < diag_thick from
the diagonal operate in double precision ("DP"); all farther tiles operate in
single precision ("SP").  On TPU there is no fast fp64, so the production
pair is {hi=fp32, lo=bf16}; the paper's literal {fp64, fp32} pair is kept for
CPU statistical validation (see DESIGN.md "Hardware adaptation").

The policy also covers:
  * "full"  -- DP(100%), the paper's reference baseline;
  * "dst"   -- Diagonal-Super-Tile / independent-blocks tapering baseline
               (off-band set to ZERO, paper Sec. V-B);
  * "three_tier" -- the paper's stated future work: hi / lo / lo2 (fp8) with
               two distance thresholds (beyond-paper deliverable).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    mode: str                 # "full" | "mixed" | "dst" | "three_tier"
    hi: Any                   # band dtype
    lo: Any                   # off-band dtype ("mixed"/"three_tier")
    diag_thick: int           # band half-width in tiles (>= 1)
    lo2: Any = None           # far-off-band dtype ("three_tier")
    diag_thick2: int = 0      # second threshold in tiles ("three_tier")
    solve_dtype: Any = jnp.float32  # dtype lo-precision TRSMs execute in
    accum_dtype: Any = jnp.float32  # accumulator for lo GEMMs (MXU semantics)

    def __post_init__(self):
        if self.mode not in ("full", "mixed", "dst", "three_tier"):
            raise ValueError(f"unknown policy mode {self.mode!r}")
        if self.diag_thick < 1:
            raise ValueError(f"diag_thick must be >= 1, got {self.diag_thick}")
        for field in ("solve_dtype", "accum_dtype"):
            value = getattr(self, field)
            try:
                dt = jnp.dtype(value)
            except TypeError as e:
                raise ValueError(f"{field} is not a dtype: {value!r}") from e
            if not jnp.issubdtype(dt, jnp.floating):
                raise ValueError(
                    f"{field} must be a floating dtype, got {dt}")
        # a narrower accumulator than the lo storage would silently round
        # every MXU partial product below the paper's SP error model
        try:
            lo_bits = jnp.finfo(jnp.dtype(self.lo)).bits
        except (TypeError, ValueError):
            lo_bits = None  # non-float lo is caught by downstream tile math
        accum_bits = jnp.finfo(jnp.dtype(self.accum_dtype)).bits
        if lo_bits is not None and accum_bits < lo_bits:
            raise ValueError(
                f"accum_dtype ({jnp.dtype(self.accum_dtype)}, {accum_bits} "
                f"bits) must be at least as wide as lo "
                f"({jnp.dtype(self.lo)}, {lo_bits} bits)")
        if self.mode == "three_tier":
            if self.lo2 is None:
                raise ValueError("three_tier policy needs a lo2 dtype")
            if self.diag_thick2 <= self.diag_thick:
                # diag_thick2 == diag_thick would silently erase the lo tier;
                # ask for an explicit two-tier policy instead
                raise ValueError(
                    f"three_tier needs diag_thick2 > diag_thick, got "
                    f"diag_thick2={self.diag_thick2} <= "
                    f"diag_thick={self.diag_thick}")

    # ---- constructors -------------------------------------------------
    @staticmethod
    def full(hi=jnp.float32) -> "PrecisionPolicy":
        """DP(100%): the paper's reference."""
        return PrecisionPolicy(mode="full", hi=hi, lo=hi, diag_thick=1 << 30,
                               solve_dtype=hi, accum_dtype=hi)

    @staticmethod
    def paper_cpu(diag_thick: int) -> "PrecisionPolicy":
        """The paper's literal pair: DP=fp64 band, SP=fp32 off-band.

        Requires x64 (use jax.experimental.enable_x64 or the config flag).
        """
        return PrecisionPolicy(mode="mixed", hi=jnp.float64, lo=jnp.float32,
                               diag_thick=diag_thick,
                               solve_dtype=jnp.float32, accum_dtype=jnp.float32)

    @staticmethod
    def tpu(diag_thick: int) -> "PrecisionPolicy":
        """TPU-native pair: hi=fp32 band, lo=bf16 off-band, fp32 accumulate."""
        return PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.bfloat16,
                               diag_thick=diag_thick,
                               solve_dtype=jnp.float32, accum_dtype=jnp.float32)

    @staticmethod
    def dst(diag_thick: int, hi=jnp.float32) -> "PrecisionPolicy":
        """Diagonal-Super-Tile tapering: off-band ZERO (independent blocks)."""
        return PrecisionPolicy(mode="dst", hi=hi, lo=hi, diag_thick=diag_thick,
                               solve_dtype=hi, accum_dtype=hi)

    @staticmethod
    def three_tier(diag_thick: int, diag_thick2: int) -> "PrecisionPolicy":
        """fp32 band / bf16 mid / fp8(e4m3) far -- the paper's future work."""
        return PrecisionPolicy(mode="three_tier", hi=jnp.float32,
                               lo=jnp.bfloat16, lo2=jnp.float8_e4m3fn,
                               diag_thick=diag_thick, diag_thick2=diag_thick2,
                               solve_dtype=jnp.float32, accum_dtype=jnp.float32)

    # ---- tile classification ------------------------------------------
    def tile_dtype(self, i: int, j: int):
        """Storage dtype of tile (i, j) (tile indices)."""
        d = abs(i - j)
        if self.mode == "full":
            return self.hi
        if d < self.diag_thick:
            return self.hi
        if self.mode == "three_tier" and d >= self.diag_thick2:
            return self.lo2
        if self.mode == "dst":
            return None  # zeroed / dropped
        return self.lo

    def in_band(self, i: int, j: int) -> bool:
        return abs(i - j) < self.diag_thick or self.mode == "full"

    def dp_fraction(self, p: int) -> float:
        """Fraction of lower-triangle tiles inside the DP band (for the
        paper's DP(x%)-SP(y%) labels)."""
        total = p * (p + 1) // 2
        t = min(self.diag_thick, p)
        band = t * p - t * (t - 1) // 2
        return band / total

    @staticmethod
    def from_dp_percent(p: int, dp_percent: float, pair: str = "tpu") -> "PrecisionPolicy":
        """Build a policy whose band covers ~dp_percent of the lower tiles.

        Matches the paper's DP(x%)-SP(y%) naming: solves for diag_thick t
        such that band_tiles / total_tiles ~ x%.
        """
        total = p * (p + 1) / 2
        best_t, best_err = 1, float("inf")
        for t in range(1, p + 1):
            frac = (t * p - t * (t - 1) / 2) / total
            err = abs(frac - dp_percent)
            if err < best_err:
                best_t, best_err = t, err
        ctor = {"tpu": PrecisionPolicy.tpu, "paper_cpu": PrecisionPolicy.paper_cpu,
                "dst": PrecisionPolicy.dst}[pair]
        return ctor(best_t)


def lo_matmul(a, b, policy: PrecisionPolicy, tier=None):
    """Low-precision GEMM with explicit accumulator semantics.

    paper_cpu pair: fp32 x fp32 -> fp32 (literal sgemm).
    tpu pair:       bf16 x bf16 -> fp32 accumulate (MXU), round to bf16.
    """
    lo = tier if tier is not None else policy.lo
    a = a.astype(lo)
    b = b.astype(lo)
    out = jnp.matmul(a, b, preferred_element_type=policy.accum_dtype)
    return out.astype(lo)
