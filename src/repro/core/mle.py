"""Maximum likelihood estimation drivers (paper Sec. IV-C).

The paper optimizes the likelihood with NLopt's derivative-free BOBYQA; we
provide (a) a derivative-free Nelder-Mead in log-parameter space (host loop
around a jitted likelihood -- mirrors the paper's setup, robust to the
mixed-precision likelihood's slight non-smoothness) and (b) a gradient path
(Adam on -loglik via jax.grad through the tile factorization) as the
beyond-paper alternative.

Counts likelihood evaluations/iterations so the paper's "MP needs more
iterations on strongly-correlated data" observation can be reproduced.

Both drivers can run on the batched evaluation engine
(`core/batch_engine.py`): `fit_mle_grid` is a batched iterative grid search
(every refinement level is ONE device call over the whole candidate grid),
and `neldermead`/`fit_mle` accept a batched function that evaluates the
initial simplex, the speculative reflection/expansion/contraction triple,
and shrink steps in single batched calls -- the vmap analogue of the
parallel likelihood evaluations in the ExaGeoStat follow-up work
(arXiv:1804.09137).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs


def _timed_eval(fn: Callable | None, metric: str) -> Callable | None:
    """Wrap an optimizer's (host-side, blocking) evaluation function so each
    call lands one latency sample in the `metric` histogram.  Identity when
    telemetry is off -- the optimizer hot loop pays nothing."""
    if fn is None or not obs.enabled():
        return fn

    def timed(*args):
        t0 = time.perf_counter()
        out = fn(*args)
        obs.observe(metric, time.perf_counter() - t0)
        obs.inc(metric + ".calls")
        return out

    return timed


@dataclass
class MLEResult:
    theta: np.ndarray
    loglik: float
    n_evals: int
    n_iters: int
    converged: bool
    history: list


def neldermead(fn: Callable, x0, *, xtol: float = 1e-3, ftol: float = 1e-6,
               max_iters: int = 200, scale: float = 0.25,
               fn_batch: Callable | None = None):
    """Minimize fn (host-side NM; fn is typically a jitted device function).

    Works in the unconstrained space the caller provides (we use log-theta).
    Returns (x_best, f_best, n_evals, n_iters, converged, history).

    fn_batch: optional (B, d) -> (B,) batched version of fn.  When given,
    the initial simplex and shrink steps run as single batched calls, and
    each iteration *speculatively* evaluates the reflection, expansion and
    contraction candidates together in one batched call.  That spends 3
    evals/iteration where the sequential path often needs only 1, so it
    pays off when per-eval dispatch/host-sync overhead dominates (small-n
    problems, the regime bench_batched_mle.py measures); when the O(n^3)
    factorization itself dominates, the speculative work can cost up to
    ~3x the FLOPs -- leave fn_batch unset there.  The accepted point is
    identical to the sequential algorithm's either way.
    """
    x0 = np.asarray(x0, dtype=np.float64)
    # per-evaluation latency histograms (mle.eval_seconds /
    # mle.eval_batch_seconds): each fn call is a device round-trip, the
    # paper's "time per iteration" unit
    fn = _timed_eval(fn, "mle.eval_seconds")
    fn_batch = _timed_eval(fn_batch, "mle.eval_batch_seconds")
    d = x0.size
    pts = [x0] + [x0 + scale * np.eye(d)[i] for i in range(d)]
    simplex = np.stack(pts)
    if fn_batch is not None:
        fvals = np.asarray(fn_batch(simplex), dtype=np.float64)
    else:
        fvals = np.array([float(fn(p)) for p in simplex])
    n_evals = d + 1
    history = []

    alpha, gamma, rho, sigma = 1.0, 2.0, 0.5, 0.5
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        order = np.argsort(fvals)
        simplex, fvals = simplex[order], fvals[order]
        history.append((simplex[0].copy(), fvals[0]))
        if (np.max(np.abs(simplex[1:] - simplex[0])) < xtol
                and np.max(np.abs(fvals[1:] - fvals[0])) < ftol):
            converged = True
            break
        centroid = simplex[:-1].mean(axis=0)
        xr = centroid + alpha * (centroid - simplex[-1])
        xe = centroid + gamma * (xr - centroid)
        xc = centroid + rho * (simplex[-1] - centroid)
        if fn_batch is not None:
            fr, fe, fc = np.asarray(
                fn_batch(np.stack([xr, xe, xc])), dtype=np.float64)
            n_evals += 3
        else:
            fr = float(fn(xr)); n_evals += 1
            fe = fc = None
        if fvals[0] <= fr < fvals[-2]:
            simplex[-1], fvals[-1] = xr, fr
        elif fr < fvals[0]:
            if fe is None:
                fe = float(fn(xe)); n_evals += 1
            if fe < fr:
                simplex[-1], fvals[-1] = xe, fe
            else:
                simplex[-1], fvals[-1] = xr, fr
        else:
            if fc is None:
                fc = float(fn(xc)); n_evals += 1
            if fc < fvals[-1]:
                simplex[-1], fvals[-1] = xc, fc
            else:  # shrink
                if fn_batch is not None:
                    simplex[1:] = simplex[0] + sigma * (simplex[1:] - simplex[0])
                    fvals[1:] = np.asarray(fn_batch(simplex[1:]),
                                           dtype=np.float64)
                    n_evals += d
                else:
                    for i in range(1, d + 1):
                        simplex[i] = simplex[0] + sigma * (simplex[i] - simplex[0])
                        fvals[i] = float(fn(simplex[i])); n_evals += 1
    order = np.argsort(fvals)
    return simplex[order][0], fvals[order][0], n_evals, it, converged, history


def fit_mle(loglik_fn: Callable, theta0, *, xtol: float = 1e-3,
            max_iters: int = 200, jit: bool = True,
            batched_loglik_fn: Callable | None = None) -> MLEResult:
    """Derivative-free MLE: maximize loglik over positive theta.

    theta0: initial (theta1, theta2, theta3) (or 2-vector for the profiled
    likelihood).  Optimization runs on log(theta) so positivity is free.

    batched_loglik_fn: optional (B, d) thetas -> (B,) log-likelihoods (e.g.
    `BatchEngine.loglik` or a slice-wrapper around it); enables the
    speculative batched Nelder-Mead (see `neldermead`).  When given, every
    NM evaluation goes through it, so loglik_fn may be None -- the batched
    function alone fully specifies the model.
    """
    theta0 = np.asarray(theta0, dtype=np.float64)

    neg_batch = None
    if batched_loglik_fn is not None:
        def neg_batch(xs):
            v = np.asarray(batched_loglik_fn(jnp.exp(jnp.asarray(xs))),
                           dtype=np.float64)
            return np.where(np.isfinite(v), -v, 1e10)

    if loglik_fn is None:
        if neg_batch is None:
            raise ValueError("need loglik_fn or batched_loglik_fn")

        def neg_ll_log(x):  # scalar fallback derived from the batched fn
            return float(neg_batch(np.asarray(x)[None])[0])
    else:
        ll = jax.jit(loglik_fn) if jit else loglik_fn

        def neg_ll_log(x):
            v = ll(jnp.exp(jnp.asarray(x)))
            v = float(v)
            return 1e10 if not np.isfinite(v) else -v

    with obs.span("mle.fit", driver="neldermead",
                  batched=neg_batch is not None):
        x, f, n_evals, n_iters, conv, hist = neldermead(
            neg_ll_log, np.log(theta0), xtol=xtol, max_iters=max_iters,
            fn_batch=neg_batch)
    obs.inc("mle.fits")
    return MLEResult(theta=np.exp(x), loglik=-f, n_evals=n_evals,
                     n_iters=n_iters, converged=conv,
                     history=[(np.exp(h[0]), -h[1]) for h in hist])


def fit_mle_grid(batched_loglik_fn: Callable, bounds, *, num: int = 12,
                 refine: int = 3, shrink: float = 0.4) -> MLEResult:
    """Batched iterative grid search: maximize loglik over positive theta.

    Every refinement level evaluates the FULL `num**d` candidate grid in one
    batched engine call (`batched_loglik_fn`: (B, d) -> (B,)), then recenters
    a log-space grid of `shrink` x the previous span on the incumbent.  This
    is the throughput-oriented estimation driver: `refine` device
    round-trips total (one per level) instead of one per candidate.

    bounds: sequence of (lo, hi) per parameter, in theta space (positive);
    the grid is laid out in log space like the NM driver.
    """
    bounds = np.asarray(bounds, dtype=np.float64)
    if bounds.ndim != 2 or bounds.shape[1] != 2 or np.any(bounds <= 0.0):
        raise ValueError("bounds must be (d, 2) with positive entries")
    batched_loglik_fn = _timed_eval(batched_loglik_fn,
                                    "mle.eval_batch_seconds")
    d = bounds.shape[0]
    lo0, hi0 = np.log(bounds[:, 0]), np.log(bounds[:, 1])
    lo, hi = lo0.copy(), hi0.copy()
    best_x, best_f = None, -np.inf
    n_evals = 0
    history = []
    with obs.span("mle.fit", driver="grid", levels=refine):
        for _ in range(refine):
            axes = [np.linspace(lo[i], hi[i], num) for i in range(d)]
            mesh = np.stack(np.meshgrid(*axes, indexing="ij"),
                            axis=-1).reshape(-1, d)
            ll = np.asarray(batched_loglik_fn(jnp.exp(jnp.asarray(mesh))),
                            dtype=np.float64)
            ll = np.where(np.isfinite(ll), ll, -np.inf)
            n_evals += mesh.shape[0]
            k = int(np.argmax(ll))
            if ll[k] > best_f:
                best_f, best_x = float(ll[k]), mesh[k].copy()
            if best_x is None:
                raise ValueError(
                    "fit_mle_grid: every candidate log-likelihood in the "
                    f"first {mesh.shape[0]}-point grid level was non-finite; "
                    "widen or shift `bounds` (the covariance is likely not "
                    "SPD there)")
            history.append((np.exp(best_x), best_f))
            # recenter on the incumbent, clamped so refined grids (and hence
            # the returned theta) never leave the caller's bounds box
            span = (hi - lo) * shrink
            lo = np.clip(best_x - span / 2.0, lo0, hi0)
            hi = np.clip(best_x + span / 2.0, lo0, hi0)
    obs.inc("mle.fits")
    return MLEResult(theta=np.exp(best_x), loglik=best_f, n_evals=n_evals,
                     n_iters=refine, converged=True, history=history)


def fit_mle_adam(loglik_fn: Callable, theta0, *, steps: int = 150,
                 lr: float = 0.05) -> MLEResult:
    """Gradient MLE: Adam on -loglik(exp(x)) via autodiff through the
    factorization (beyond-paper path; requires a differentiable policy)."""
    x0 = jnp.log(jnp.asarray(theta0, dtype=jnp.float32))

    neg = lambda x: -loglik_fn(jnp.exp(x))
    grad_fn = jax.jit(jax.value_and_grad(neg))

    @jax.jit
    def update(x, m, v, i):
        f, g = jax.value_and_grad(neg)(x)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mhat = m / (1 - 0.9 ** i)
        vhat = v / (1 - 0.999 ** i)
        x = x - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
        return x, m, v, f

    x, m, v = x0, jnp.zeros_like(x0), jnp.zeros_like(x0)
    f = jnp.inf
    history = []
    for i in range(1, steps + 1):
        x, m, v, f = update(x, m, v, i)
        if i % 10 == 0:
            history.append((np.exp(np.asarray(x)), -float(f)))
    f_final, _ = grad_fn(x)
    return MLEResult(theta=np.exp(np.asarray(x)), loglik=-float(f_final),
                     n_evals=steps, n_iters=steps, converged=True,
                     history=history)
