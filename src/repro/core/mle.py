"""Maximum likelihood estimation drivers (paper Sec. IV-C).

The paper optimizes the likelihood with NLopt's derivative-free BOBYQA; we
provide (a) a derivative-free Nelder-Mead in log-parameter space (host loop
around a jitted likelihood -- mirrors the paper's setup, robust to the
mixed-precision likelihood's slight non-smoothness) and (b) a gradient path
(Adam on -loglik via jax.grad through the tile factorization) as the
beyond-paper alternative.

Counts likelihood evaluations/iterations so the paper's "MP needs more
iterations on strongly-correlated data" observation can be reproduced.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class MLEResult:
    theta: np.ndarray
    loglik: float
    n_evals: int
    n_iters: int
    converged: bool
    history: list


def neldermead(fn: Callable, x0, *, xtol: float = 1e-3, ftol: float = 1e-6,
               max_iters: int = 200, scale: float = 0.25):
    """Minimize fn (host-side NM; fn is typically a jitted device function).

    Works in the unconstrained space the caller provides (we use log-theta).
    Returns (x_best, f_best, n_evals, n_iters, converged, history).
    """
    x0 = np.asarray(x0, dtype=np.float64)
    d = x0.size
    pts = [x0] + [x0 + scale * np.eye(d)[i] for i in range(d)]
    simplex = np.stack(pts)
    fvals = np.array([float(fn(p)) for p in simplex])
    n_evals = d + 1
    history = []

    alpha, gamma, rho, sigma = 1.0, 2.0, 0.5, 0.5
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        order = np.argsort(fvals)
        simplex, fvals = simplex[order], fvals[order]
        history.append((simplex[0].copy(), fvals[0]))
        if (np.max(np.abs(simplex[1:] - simplex[0])) < xtol
                and np.max(np.abs(fvals[1:] - fvals[0])) < ftol):
            converged = True
            break
        centroid = simplex[:-1].mean(axis=0)
        xr = centroid + alpha * (centroid - simplex[-1])
        fr = float(fn(xr)); n_evals += 1
        if fvals[0] <= fr < fvals[-2]:
            simplex[-1], fvals[-1] = xr, fr
        elif fr < fvals[0]:
            xe = centroid + gamma * (xr - centroid)
            fe = float(fn(xe)); n_evals += 1
            if fe < fr:
                simplex[-1], fvals[-1] = xe, fe
            else:
                simplex[-1], fvals[-1] = xr, fr
        else:
            xc = centroid + rho * (simplex[-1] - centroid)
            fc = float(fn(xc)); n_evals += 1
            if fc < fvals[-1]:
                simplex[-1], fvals[-1] = xc, fc
            else:  # shrink
                for i in range(1, d + 1):
                    simplex[i] = simplex[0] + sigma * (simplex[i] - simplex[0])
                    fvals[i] = float(fn(simplex[i])); n_evals += 1
    order = np.argsort(fvals)
    return simplex[order][0], fvals[order][0], n_evals, it, converged, history


def fit_mle(loglik_fn: Callable, theta0, *, xtol: float = 1e-3,
            max_iters: int = 200, jit: bool = True) -> MLEResult:
    """Derivative-free MLE: maximize loglik over positive theta.

    theta0: initial (theta1, theta2, theta3) (or 2-vector for the profiled
    likelihood).  Optimization runs on log(theta) so positivity is free.
    """
    theta0 = np.asarray(theta0, dtype=np.float64)
    ll = jax.jit(loglik_fn) if jit else loglik_fn

    def neg_ll_log(x):
        v = ll(jnp.exp(jnp.asarray(x)))
        v = float(v)
        return 1e10 if not np.isfinite(v) else -v

    x, f, n_evals, n_iters, conv, hist = neldermead(
        neg_ll_log, np.log(theta0), xtol=xtol, max_iters=max_iters)
    return MLEResult(theta=np.exp(x), loglik=-f, n_evals=n_evals,
                     n_iters=n_iters, converged=conv,
                     history=[(np.exp(h[0]), -h[1]) for h in hist])


def fit_mle_adam(loglik_fn: Callable, theta0, *, steps: int = 150,
                 lr: float = 0.05) -> MLEResult:
    """Gradient MLE: Adam on -loglik(exp(x)) via autodiff through the
    factorization (beyond-paper path; requires a differentiable policy)."""
    x0 = jnp.log(jnp.asarray(theta0, dtype=jnp.float32))

    neg = lambda x: -loglik_fn(jnp.exp(x))
    grad_fn = jax.jit(jax.value_and_grad(neg))

    @jax.jit
    def update(x, m, v, i):
        f, g = jax.value_and_grad(neg)(x)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mhat = m / (1 - 0.9 ** i)
        vhat = v / (1 - 0.999 ** i)
        x = x - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
        return x, m, v, f

    x, m, v = x0, jnp.zeros_like(x0), jnp.zeros_like(x0)
    f = jnp.inf
    history = []
    for i in range(1, steps + 1):
        x, m, v, f = update(x, m, v, i)
        if i % 10 == 0:
            history.append((np.exp(np.asarray(x)), -float(f)))
    f_final, _ = grad_fn(x)
    return MLEResult(theta=np.exp(np.asarray(x)), loglik=-float(f_final),
                     n_evals=steps, n_iters=steps, converged=True,
                     history=history)
