"""Gaussian log-likelihood evaluation (paper Eqs. 2-3) on tile Cholesky.

One likelihood evaluation = build Sigma(theta) from the Matern kernel,
factor it with the selected precision policy, then

  l(theta) = -n/2 log(2 pi) - sum_i log L_ii - 1/2 || L^{-1} Z ||^2 .

The profiled form (Eq. 3) treats theta1 as a multiplicative scale computed
in closed form, leaving a 2-parameter optimization over (theta2, theta3):

  theta1_opt = Z^T SigmaTilde^{-1} Z / n,
  l* = -n/2 log(2 pi) - n/2 - n/2 log(theta1_opt) - log|L-tilde| .
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from ..covariance.matern import matern_covariance
from .precision import PrecisionPolicy
from .tile_cholesky import dst_cholesky, reference_cholesky, tile_cholesky


def loglik_from_factor(l, z):
    """Eq. 2 given the lower Cholesky factor of Sigma."""
    n = z.shape[0]
    z = z.astype(l.dtype)
    logdet_half = jnp.sum(jnp.log(jnp.diagonal(l)))
    w = solve_triangular(l, z, lower=True)
    quad = jnp.sum(w * w)
    return -0.5 * n * jnp.log(2.0 * jnp.pi) - logdet_half - 0.5 * quad


def profiled_loglik_from_factor(l, z):
    """Eq. 3: profile out theta1. `l` factors the CORRELATION matrix."""
    n = z.shape[0]
    z = z.astype(l.dtype)
    logdet_half = jnp.sum(jnp.log(jnp.diagonal(l)))
    w = solve_triangular(l, z, lower=True)
    theta1_opt = jnp.sum(w * w) / n
    ll = (-0.5 * n * jnp.log(2.0 * jnp.pi) - 0.5 * n
          - 0.5 * n * jnp.log(theta1_opt) - logdet_half)
    return ll, theta1_opt


def dst_loglik(blocks, z):
    """Eq. 2 for the block-diagonal DST factor (independent blocks)."""
    n = z.shape[0]
    total = -0.5 * n * jnp.log(2.0 * jnp.pi)
    for sl, l in blocks:
        zb = z[sl].astype(l.dtype)
        w = solve_triangular(l, zb, lower=True)
        total = total - jnp.sum(jnp.log(jnp.diagonal(l))) - 0.5 * jnp.sum(w * w)
    return total


def build_covariance(locs, theta, *, nu_static=None, metric="euclidean",
                     nugget=0.0, jitter=0.0, dtype=None):
    cov = matern_covariance(locs, locs, theta, nu_static=nu_static,
                            metric=metric, nugget=nugget)
    if jitter:
        cov = cov + jitter * jnp.eye(cov.shape[0], dtype=cov.dtype)
    if dtype is not None:
        cov = cov.astype(dtype)
    return cov


def make_loglik(locs, z, policy: PrecisionPolicy, *, nb: int = 128,
                nu_static=None, metric="euclidean", nugget=0.0,
                jitter=1e-6, profiled=False, use_tiles=None):
    """Return theta -> log-likelihood under the given precision policy.

    use_tiles: force the tile path even for mode="full" (None = auto: tile
    path for mixed/three_tier, plain LAPACK-style for full).
    """
    locs = jnp.asarray(locs)
    z = jnp.asarray(z)

    def loglik(theta):
        theta = jnp.asarray(theta)
        cov_theta = jnp.array([jnp.asarray(1.0, theta.dtype), theta[0], theta[1]]) \
            if profiled else theta
        cov = build_covariance(locs, cov_theta, nu_static=nu_static,
                               metric=metric, nugget=nugget, jitter=jitter,
                               dtype=policy.hi)
        if policy.mode == "dst":
            blocks = dst_cholesky(cov, nb, policy.diag_thick, hi=policy.hi)
            if profiled:
                raise NotImplementedError("profiled DST not needed")
            return dst_loglik(blocks, z)
        tiled = use_tiles if use_tiles is not None else policy.mode != "full"
        l = tile_cholesky(cov, nb, policy) if tiled else reference_cholesky(cov, policy.hi)
        if profiled:
            ll, _ = profiled_loglik_from_factor(l, z)
            return ll
        return loglik_from_factor(l, z)

    return loglik
