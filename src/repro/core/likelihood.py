"""Gaussian log-likelihood evaluation (paper Eqs. 2-3) on tile Cholesky.

One likelihood evaluation = build Sigma(theta) from the Matern kernel,
factor it with the selected precision policy, then

  l(theta) = -n/2 log(2 pi) - sum_i log L_ii - 1/2 || L^{-1} Z ||^2 .

The profiled form (Eq. 3) treats theta1 as a multiplicative scale computed
in closed form, leaving a 2-parameter optimization over (theta2, theta3):

  theta1_opt = Z^T SigmaTilde^{-1} Z / n,
  l* = -n/2 log(2 pi) - n/2 - n/2 log(theta1_opt) - log|L-tilde| .
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from ..covariance.matern import matern_covariance
from .precision import PrecisionPolicy
from .tile_cholesky import dst_cholesky, reference_cholesky, tile_cholesky


def _forward_solve_vec(l, z):
    """w = L^{-1} z with l (..., n, n) and z (n,); returns (..., n)."""
    zb = jnp.broadcast_to(z, l.shape[:-2] + z.shape[-1:])
    return solve_triangular(l, zb[..., None], lower=True)[..., 0]


def loglik_from_factor(l, z):
    """Eq. 2 given the lower Cholesky factor of Sigma.

    l may carry leading batch axes (one factor per candidate theta); the
    result then has those batch axes.
    """
    n = z.shape[-1]
    z = z.astype(l.dtype)
    diag = jnp.diagonal(l, axis1=-2, axis2=-1)
    logdet_half = jnp.sum(jnp.log(diag), axis=-1)
    w = _forward_solve_vec(l, z)
    quad = jnp.sum(w * w, axis=-1)
    return -0.5 * n * jnp.log(2.0 * jnp.pi) - logdet_half - 0.5 * quad


def profiled_loglik_from_factor(l, z):
    """Eq. 3: profile out theta1. `l` factors the CORRELATION matrix."""
    n = z.shape[-1]
    z = z.astype(l.dtype)
    diag = jnp.diagonal(l, axis1=-2, axis2=-1)
    logdet_half = jnp.sum(jnp.log(diag), axis=-1)
    w = _forward_solve_vec(l, z)
    theta1_opt = jnp.sum(w * w, axis=-1) / n
    ll = (-0.5 * n * jnp.log(2.0 * jnp.pi) - 0.5 * n
          - 0.5 * n * jnp.log(theta1_opt) - logdet_half)
    return ll, theta1_opt


def dst_loglik(blocks, z):
    """Eq. 2 for the block-diagonal DST factor (independent blocks).

    Block factors may carry leading batch axes, like loglik_from_factor.
    """
    n = z.shape[-1]
    total = -0.5 * n * jnp.log(2.0 * jnp.pi)
    for sl, l in blocks:
        zb = z[sl].astype(l.dtype)
        diag = jnp.diagonal(l, axis1=-2, axis2=-1)
        w = _forward_solve_vec(l, zb)
        total = total - jnp.sum(jnp.log(diag), axis=-1) - 0.5 * jnp.sum(w * w, axis=-1)
    return total


def build_covariance(locs, theta, *, nu_static=None, metric="euclidean",
                     nugget=0.0, jitter=0.0, dtype=None):
    cov = matern_covariance(locs, locs, theta, nu_static=nu_static,
                            metric=metric, nugget=nugget)
    if jitter:
        cov = cov + jitter * jnp.eye(cov.shape[-1], dtype=cov.dtype)
    if dtype is not None:
        cov = cov.astype(dtype)
    return cov


def make_factor_fn(locs, policy: PrecisionPolicy, *, nb: int = 128,
                   nu_static=None, metric="euclidean", nugget=0.0,
                   jitter=1e-6, use_tiles=None):
    """Return theta -> lower Cholesky factor of Sigma(theta).

    This is THE covariance-build + factor-path selection (tiled Algorithm 1
    vs dense reference, per `use_tiles`/policy mode), shared by `make_loglik`
    and the batch engine's fused evaluate so the two can never diverge.
    Not applicable to mode="dst" (block factors; see `dst_cholesky`).
    """
    if policy.mode == "dst":
        raise ValueError("dst mode factors independent blocks; "
                         "use dst_cholesky")
    locs = jnp.asarray(locs)
    tiled = use_tiles if use_tiles is not None else policy.mode != "full"

    def factor(theta):
        cov = build_covariance(locs, jnp.asarray(theta), nu_static=nu_static,
                               metric=metric, nugget=nugget, jitter=jitter,
                               dtype=policy.hi)
        return tile_cholesky(cov, nb, policy) if tiled \
            else reference_cholesky(cov, policy.hi)

    return factor


def make_loglik(locs, z, policy: PrecisionPolicy, *, nb: int = 128,
                nu_static=None, metric="euclidean", nugget=0.0,
                jitter=1e-6, profiled=False, use_tiles=None):
    """Return theta -> log-likelihood under the given precision policy.

    use_tiles: force the tile path even for mode="full" (None = auto: tile
    path for mixed/three_tier, plain LAPACK-style for full).

    The returned closure accepts a single theta (3,) or a stacked batch
    (..., 3) of candidates, returning matching leading axes of
    log-likelihoods (one factorization per candidate, batched tile ops).
    """
    locs = jnp.asarray(locs)
    z = jnp.asarray(z)
    factor = None if policy.mode == "dst" else make_factor_fn(
        locs, policy, nb=nb, nu_static=nu_static, metric=metric,
        nugget=nugget, jitter=jitter, use_tiles=use_tiles)

    def loglik(theta):
        theta = jnp.asarray(theta)
        cov_theta = jnp.concatenate(
            [jnp.ones_like(theta[..., :1]), theta[..., :2]], axis=-1) \
            if profiled else theta
        if policy.mode == "dst":
            if profiled:
                raise NotImplementedError("profiled DST not needed")
            cov = build_covariance(locs, cov_theta, nu_static=nu_static,
                                   metric=metric, nugget=nugget,
                                   jitter=jitter, dtype=policy.hi)
            blocks = dst_cholesky(cov, nb, policy.diag_thick, hi=policy.hi)
            return dst_loglik(blocks, z)
        l = factor(cov_theta)
        if profiled:
            ll, _ = profiled_loglik_from_factor(l, z)
            return ll
        return loglik_from_factor(l, z)

    return loglik
