from .precision import PrecisionPolicy, lo_matmul
from .tile_cholesky import (
    assemble_lower,
    dst_assemble,
    dst_cholesky,
    reference_cholesky,
    split_tiles,
    tile_cholesky,
)
from .panel_cholesky import (
    assemble_from_banded,
    banded_forward_solve,
    banded_loglik,
    build_banded_covariance,
    geostat_loglik_step,
    panel_cholesky_banded,
)
from .likelihood import (
    build_covariance,
    dst_loglik,
    loglik_from_factor,
    make_loglik,
    profiled_loglik_from_factor,
)
from .mle import MLEResult, fit_mle, fit_mle_adam, neldermead
from .kriging import kfold_pmse, krige, pmse

__all__ = [
    "PrecisionPolicy", "lo_matmul",
    "assemble_lower", "dst_assemble", "dst_cholesky", "reference_cholesky",
    "split_tiles", "tile_cholesky",
    "assemble_from_banded", "banded_forward_solve", "banded_loglik",
    "build_banded_covariance", "geostat_loglik_step", "panel_cholesky_banded",
    "build_covariance", "dst_loglik", "loglik_from_factor", "make_loglik",
    "profiled_loglik_from_factor",
    "MLEResult", "fit_mle", "fit_mle_adam", "neldermead",
    "kfold_pmse", "krige", "pmse",
]
