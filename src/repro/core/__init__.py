from .precision import PrecisionPolicy, lo_matmul
from .tile_cholesky import (
    assemble_lower,
    dst_assemble,
    dst_cholesky,
    reference_cholesky,
    split_tiles,
    tile_cholesky,
)
from .panel_cholesky import (
    assemble_from_banded,
    banded_forward_solve,
    banded_loglik,
    build_banded_covariance,
    geostat_loglik_step,
    panel_cholesky_banded,
)
from .likelihood import (
    build_covariance,
    dst_loglik,
    loglik_from_factor,
    make_factor_fn,
    make_loglik,
    profiled_loglik_from_factor,
)
from .mle import MLEResult, fit_mle, fit_mle_adam, fit_mle_grid, neldermead
from .kriging import kfold_pmse, krige, krige_pmse, pmse
from .batch_engine import (
    BatchEngine,
    BatchPlan,
    BatchResult,
    chunked,
    evaluate_batch,
)

__all__ = [
    "PrecisionPolicy", "lo_matmul",
    "assemble_lower", "dst_assemble", "dst_cholesky", "reference_cholesky",
    "split_tiles", "tile_cholesky",
    "assemble_from_banded", "banded_forward_solve", "banded_loglik",
    "build_banded_covariance", "geostat_loglik_step", "panel_cholesky_banded",
    "build_covariance", "dst_loglik", "loglik_from_factor", "make_factor_fn",
    "make_loglik", "profiled_loglik_from_factor",
    "MLEResult", "fit_mle", "fit_mle_adam", "fit_mle_grid", "neldermead",
    "kfold_pmse", "krige", "krige_pmse", "pmse",
    "BatchEngine", "BatchPlan", "BatchResult", "chunked", "evaluate_batch",
]
