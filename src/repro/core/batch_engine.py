"""Batched mixed-precision likelihood engine (vmap-first, DESIGN.md Sec. 4).

The paper's hot path is repeated evaluation of the Gaussian log-likelihood:
one mixed-precision tile Cholesky per candidate parameter vector theta.
ExaGeoStat amortizes that kernel across an optimization run with StarPU
task-level concurrency; the jax_pallas analogue is to evaluate *many*
candidate thetas at once so every tile op (POTRF/TRSM/SYRK/GEMM) runs with a
leading batch axis and the accelerator never drains between factorizations.

This module plans such a batch:

  * `BatchPlan`   -- what one batch looks like: ONE `PrecisionPolicy` for the
                     whole batch, tile size, evaluation path ("tile" = the
                     faithful Algorithm-1 engine, "panel" = the banded
                     performance path), and an optional chunk size that bounds
                     peak memory (`lax.map` over chunks of `vmap`-width work).
  * `BatchEngine` -- jit-compiled batched log-likelihood and batched kriging
                     PMSE over a (B, 3) stack of candidate thetas.
  * `BatchResult` -- per-candidate log-likelihoods (+ optional PMSE) and the
                     batch argmax.

The tile path exploits the *native* leading-batch support in
`covariance/matern.py`, `core/tile_cholesky.py`, `core/likelihood.py` and
`core/kriging.py` (no vmap needed -- tile ops are themselves batched); the
panel path wraps `geostat_loglik_step` in `jax.vmap`.  Chunking matters when
B x n x n covariance stacks would not fit memory: chunks run sequentially
under `lax.map`, candidates inside a chunk run batched.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..covariance.matern import matern_covariance
from .kriging import krige_from_factor, krige_pmse, pmse
from .likelihood import loglik_from_factor, make_factor_fn, make_loglik
from .panel_cholesky import geostat_loglik_step
from .precision import PrecisionPolicy


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """How a batch of candidate thetas is evaluated.

    One policy per batch: all candidates share the precision policy (and
    hence one compiled program), matching the paper's setup where the
    precision variant is fixed for a whole optimization run.
    """
    policy: PrecisionPolicy
    nb: int = 128                     # tile size
    chunk_size: Optional[int] = None  # None = one vmap over the whole batch
    path: str = "tile"                # "tile" | "panel"
    nu_static: Optional[float] = None
    metric: str = "euclidean"
    nugget: float = 0.0
    jitter: float = 1e-6
    profiled: bool = False
    use_tiles: Optional[bool] = None  # tile path only
    off_update: str = "square"        # panel path only

    def __post_init__(self):
        if self.path not in ("tile", "panel"):
            raise ValueError(f"unknown path {self.path!r}")
        if self.path == "panel" and self.policy.mode == "dst":
            raise ValueError("panel path has no DST variant")
        if self.path == "panel" and (self.nugget or self.profiled
                                     or self.use_tiles is not None):
            raise ValueError(
                "panel path supports neither nugget, profiled, nor "
                "use_tiles -- use path='tile' for those")
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")


@dataclasses.dataclass
class BatchResult:
    """Per-candidate outputs of one batched evaluation."""
    thetas: np.ndarray                 # (B, d)
    logliks: np.ndarray                # (B,)
    pmse: Optional[np.ndarray] = None  # (B,) if the plan scored kriging

    @property
    def best_index(self) -> int:
        finite = np.isfinite(self.logliks)
        if not np.any(finite):
            raise ValueError(
                "every candidate log-likelihood in the batch is non-finite; "
                "the covariance is likely not SPD anywhere in the candidate "
                "set -- there is no meaningful best_theta")
        ll = np.where(finite, self.logliks, -np.inf)
        return int(np.argmax(ll))

    @property
    def best_theta(self) -> np.ndarray:
        return self.thetas[self.best_index]

    @property
    def best_loglik(self) -> float:
        return float(self.logliks[self.best_index])


def chunked(fn: Callable, chunk_size: Optional[int] = None) -> Callable:
    """Wrap a batched fn (leading axis B) to process B in fixed-size chunks.

    The batch is padded (repeating the last element) to a chunk multiple,
    reshaped to (num_chunks, chunk_size, ...), and fed through `lax.map`,
    so peak memory is one chunk's worth while each chunk stays fully
    batched.  With chunk_size=None (or >= B) this is `fn` itself.
    """
    if chunk_size is None:
        return fn

    def run(x):
        b = x.shape[0]
        if b <= chunk_size:
            return fn(x)
        pad = (-b) % chunk_size
        if pad:
            x = jnp.concatenate(
                [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])])
        xc = x.reshape(-1, chunk_size, *x.shape[1:])
        out = jax.lax.map(fn, xc)
        return jax.tree_util.tree_map(
            lambda o: o.reshape((-1,) + o.shape[2:])[:b], out)

    return run


class BatchEngine:
    """Batched log-likelihood (+ kriging PMSE) over candidate thetas.

    >>> engine = BatchEngine(locs, z, BatchPlan(policy, nb=32, nu_static=0.5))
    >>> ll = engine.loglik(thetas)          # (B,) from (B, 3), one jit call
    >>> res = engine.evaluate(thetas)       # BatchResult with argmax

    Prediction scoring is enabled by passing held-out locations/truth:

    >>> engine = BatchEngine(locs, z, plan, locs_new=s_new, y_true=y)
    >>> res = engine.evaluate(thetas)       # res.pmse per candidate
    """

    def __init__(self, locs, z, plan: BatchPlan, *, locs_new=None, y_true=None):
        self.plan = plan
        self.locs = jnp.asarray(locs)
        self.z = jnp.asarray(z)
        self.locs_new = None if locs_new is None else jnp.asarray(locs_new)
        self.y_true = None if y_true is None else jnp.asarray(y_true)

        single = self._build_single_loglik()
        batched = self._batch(single)
        self._loglik_single = jax.jit(single)
        self._loglik_batch = jax.jit(chunked(batched, plan.chunk_size))

        self._pmse_batch = None
        self._eval_batch = None
        if self.locs_new is not None:
            if self.y_true is None:
                raise ValueError("y_true is required when locs_new is given")
            if plan.profiled:
                raise ValueError(
                    "profiled plans take (theta2, theta3) candidates with "
                    "the variance profiled out, which kriging cannot score; "
                    "use a non-profiled plan (full thetas) with locs_new")
            p = plan
            pol = p.policy if p.policy.mode != "dst" \
                else PrecisionPolicy.full(p.policy.hi)  # DST predicts densely
            # DST's dense fallback must not inherit the tiled override
            pmse_use_tiles = p.use_tiles if p.policy.mode != "dst" else None

            # NOTE: kriging always factors Sigma_oo through the tile-path
            # selection (krige -> make_factor_fn).  For path="panel" plans
            # that means loglik and PMSE use different *numerical paths*
            # over the SAME covariance model (the two factorizations agree
            # to fp noise; tests assert tile/panel likelihood parity) --
            # unlike nugget/profiled, nothing model-level diverges, so this
            # is allowed rather than rejected.
            def single_pmse(theta):
                return krige_pmse(self.locs, self.z, self.locs_new,
                                  self.y_true, theta, pol, nb=p.nb,
                                  nu_static=p.nu_static, metric=p.metric,
                                  nugget=p.nugget, jitter=p.jitter,
                                  use_tiles=pmse_use_tiles)

            self._pmse_batch = jax.jit(
                chunked(self._batch(single_pmse), p.chunk_size))
            if p.path == "tile" and p.policy.mode != "dst":
                # fused program: the loglik factorization is reused for the
                # kriging solves, halving the dominant O(B n^3) work of
                # evaluate() (dst factors independent blocks and the panel
                # path factors banded storage, so those fall back to the
                # two separate programs; profiled+locs_new was rejected
                # above)
                self._eval_batch = jax.jit(
                    chunked(self._build_single_eval(), p.chunk_size))

    # ---- plumbing ------------------------------------------------------
    def _build_single_loglik(self) -> Callable:
        p = self.plan
        if p.path == "panel":
            def single(theta):
                return geostat_loglik_step(
                    self.locs, self.z, theta, nb=p.nb, policy=p.policy,
                    nu_static=p.nu_static, metric=p.metric, jitter=p.jitter,
                    off_update=p.off_update)
            return single
        return make_loglik(self.locs, self.z, p.policy, nb=p.nb,
                           nu_static=p.nu_static, metric=p.metric,
                           nugget=p.nugget, jitter=p.jitter,
                           profiled=p.profiled, use_tiles=p.use_tiles)

    def _build_single_eval(self) -> Callable:
        """(.., 3) theta -> (loglik, pmse) sharing ONE factorization."""
        p = self.plan
        pol = p.policy
        # the same factor builder make_loglik uses, so engine.loglik and
        # the fused program can never select different covariance/factor
        # paths for one plan
        factor = make_factor_fn(self.locs, pol, nb=p.nb,
                                nu_static=p.nu_static, metric=p.metric,
                                nugget=p.nugget, jitter=p.jitter,
                                use_tiles=p.use_tiles)

        def single(theta):
            theta = jnp.asarray(theta)
            l = factor(theta)
            ll = loglik_from_factor(l, self.z)
            sigma_no = matern_covariance(
                self.locs_new, self.locs, theta, nu_static=p.nu_static,
                metric=p.metric).astype(pol.hi)
            mu = krige_from_factor(l, self.z, sigma_no)
            return ll, pmse(mu, self.y_true)

        return single

    def _batch(self, single: Callable) -> Callable:
        # Tile-path functions are natively batched over theta's leading
        # axes; the panel path's in-place banded updates index tiles by
        # position, so it batches via vmap instead.
        if self.plan.path == "panel":
            return jax.vmap(single)
        return single

    def _prepare(self, thetas) -> jnp.ndarray:
        """Normalize candidates to a (B, 3) stack.  When the plan pins the
        smoothness (`nu_static`, non-profiled), (B, 2) candidates over
        (variance, range) are accepted and the pinned nu column is appended
        here -- callers don't have to plumb a dummy column themselves."""
        thetas = jnp.atleast_2d(jnp.asarray(thetas))
        if (thetas.shape[-1] == 2 and self.plan.nu_static is not None
                and not self.plan.profiled):
            nu = jnp.full(thetas.shape[:-1] + (1,), self.plan.nu_static,
                          thetas.dtype)
            thetas = jnp.concatenate([thetas, nu], axis=-1)
        return thetas

    # ---- public API ----------------------------------------------------
    # Every public entry point is a jit dispatch boundary (the host hands a
    # candidate batch to the device and blocks on the answer), so each gets
    # a telemetry span + a candidates-evaluated counter when obs is on.
    def loglik(self, thetas) -> jnp.ndarray:
        """(B, d) candidate thetas -> (B,) log-likelihoods, one device call."""
        thetas = self._prepare(thetas)
        with obs.span("batch.loglik", b=int(thetas.shape[0]),
                      path=self.plan.path) as sp:
            out = self._loglik_batch(thetas)
            if sp is not obs.NULL_SPAN:
                obs.inc("batch.candidates", int(thetas.shape[0]))
                out.block_until_ready()
            return out

    def loglik_sequential(self, thetas) -> np.ndarray:
        """Reference path: one jitted evaluation per candidate with a host
        sync after each, exactly like the pre-batch-engine optimizer loop in
        `core/mle.py` (`float(fn(p))` per candidate).  Kept for benchmarks
        and parity tests."""
        thetas = self._prepare(thetas)
        with obs.span("batch.loglik_sequential", b=int(thetas.shape[0])):
            return np.array([float(self._loglik_single(t)) for t in thetas])

    def krige_pmse(self, thetas) -> jnp.ndarray:
        """(B, d) candidate thetas -> (B,) held-out kriging PMSE."""
        if self._pmse_batch is None:
            raise ValueError("engine was built without locs_new/y_true")
        thetas = self._prepare(thetas)
        with obs.span("batch.krige_pmse", b=int(thetas.shape[0])) as sp:
            out = self._pmse_batch(thetas)
            if sp is not obs.NULL_SPAN:
                out.block_until_ready()
            return out

    def evaluate(self, thetas, *, with_pmse: Optional[bool] = None) -> BatchResult:
        """One planned batch: log-likelihoods (+ PMSE when available).

        When the plan allows it, this runs the fused program that reuses
        the likelihood's Cholesky factor for the kriging solves (one
        factorization per candidate instead of two)."""
        thetas = self._prepare(thetas)
        if with_pmse is None:
            with_pmse = self._pmse_batch is not None
        with obs.span("batch.evaluate", b=int(thetas.shape[0]),
                      fused=bool(with_pmse and self._eval_batch is not None)):
            if with_pmse and self._eval_batch is not None:
                obs.inc("batch.candidates", int(thetas.shape[0]))
                ll, scores = self._eval_batch(thetas)
                return BatchResult(thetas=np.asarray(thetas),
                                   logliks=np.asarray(ll),
                                   pmse=np.asarray(scores))
            ll = np.asarray(self.loglik(thetas))
            scores = np.asarray(self.krige_pmse(thetas)) if with_pmse else None
            return BatchResult(thetas=np.asarray(thetas), logliks=ll,
                               pmse=scores)


def evaluate_batch(locs, z, thetas, plan: BatchPlan, *, locs_new=None,
                   y_true=None) -> BatchResult:
    """One-shot convenience wrapper around `BatchEngine.evaluate`."""
    engine = BatchEngine(locs, z, plan, locs_new=locs_new, y_true=y_true)
    return engine.evaluate(thetas)
