"""Kriging prediction + PMSE + k-fold cross validation (paper Sec. VIII-D).

Given observations Z at locations S_obs and estimated theta-hat, the
conditional (kriging) predictor at new locations S_new is

  mu    = Sigma_no Sigma_oo^{-1} Z
  var   = diag(Sigma_nn - Sigma_no Sigma_oo^{-1} Sigma_on)

computed through the (mixed-precision) Cholesky factor of Sigma_oo.
PMSE over held-out truth y: mean((mu - y)^2), evaluated with k-fold CV
(k = 10 in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import solve_triangular

from ..covariance.matern import matern_covariance
from .precision import PrecisionPolicy
from .tile_cholesky import reference_cholesky, tile_cholesky


def krige(locs_obs, z_obs, locs_new, theta, policy: PrecisionPolicy, *,
          nb: int = 128, nu_static=None, metric="euclidean", jitter=1e-6,
          return_var: bool = False):
    """Kriging mean (and optionally variance) at locs_new."""
    theta = jnp.asarray(theta)
    sigma_oo = matern_covariance(locs_obs, locs_obs, theta, nu_static=nu_static,
                                 metric=metric).astype(policy.hi)
    sigma_oo = sigma_oo + jitter * jnp.eye(sigma_oo.shape[0], dtype=policy.hi)
    sigma_no = matern_covariance(locs_new, locs_obs, theta, nu_static=nu_static,
                                 metric=metric).astype(policy.hi)
    if policy.mode in ("mixed", "three_tier"):
        l = tile_cholesky(sigma_oo, nb, policy)
    else:
        l = reference_cholesky(sigma_oo, policy.hi)
    # mu = Sigma_no Sigma_oo^{-1} Z  via two triangular solves
    w = solve_triangular(l, z_obs.astype(policy.hi), lower=True)
    v = solve_triangular(l, sigma_no.T, lower=True)          # L^{-1} Sigma_on
    mu = v.T @ w
    if not return_var:
        return mu
    sigma_nn_diag = jnp.full((locs_new.shape[0],), theta[0], dtype=policy.hi)
    var = sigma_nn_diag - jnp.sum(v * v, axis=0)
    return mu, var


def pmse(mu, y_true):
    mu = jnp.asarray(mu)
    y_true = jnp.asarray(y_true).astype(mu.dtype)
    return jnp.mean((mu - y_true) ** 2)


def kfold_pmse(locs, z, theta, policy: PrecisionPolicy, *, k: int = 10,
               nb: int = 128, nu_static=None, metric="euclidean", seed: int = 0):
    """k-fold cross-validated PMSE (paper uses k=10).

    Folds must keep n_obs a multiple of nb for the tile path; we trim the
    remainder into the observation set rather than dropping data.
    """
    n = locs.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    fold_size = n // k
    scores = []
    for f in range(k):
        test_idx = perm[f * fold_size:(f + 1) * fold_size]
        train_mask = np.ones(n, dtype=bool)
        train_mask[test_idx] = False
        train_idx = np.nonzero(train_mask)[0]
        # trim training set to a tile multiple (move extras to test side? no:
        # just drop up to nb-1 points -- harmless for PMSE estimation)
        m = (len(train_idx) // nb) * nb
        if m == 0:
            raise ValueError("fold too small for tile size")
        tr = train_idx[:m]
        mu = krige(locs[tr], z[tr], locs[test_idx], theta, policy,
                   nb=nb, nu_static=nu_static, metric=metric)
        scores.append(float(pmse(mu, z[test_idx])))
    return float(np.mean(scores)), scores
