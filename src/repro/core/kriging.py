"""Kriging prediction + PMSE + k-fold cross validation (paper Sec. VIII-D).

Given observations Z at locations S_obs and estimated theta-hat, the
conditional (kriging) predictor at new locations S_new is

  mu    = Sigma_no Sigma_oo^{-1} Z
  var   = diag(Sigma_nn - Sigma_no Sigma_oo^{-1} Sigma_on)

computed through the (mixed-precision) Cholesky factor of Sigma_oo.
PMSE over held-out truth y: mean((mu - y)^2), evaluated with k-fold CV
(k = 10 in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import solve_triangular

from ..covariance.matern import matern_covariance
from .likelihood import make_factor_fn
from .precision import PrecisionPolicy


def krige_from_factor(l, z_obs, sigma_no, *, sigma_nn_diag=None):
    """Kriging mean (and variance) given a precomputed Cholesky factor.

    l: (..., n, n) lower factor of Sigma_oo; sigma_no: (..., m, n) cross
    covariance.  Sharing `l` lets callers that already factored Sigma_oo
    for the log-likelihood (the batch engine) skip the second O(n^3)
    factorization.  Returns mu, or (mu, var) when sigma_nn_diag is given.
    """
    # mu = Sigma_no Sigma_oo^{-1} Z  via two triangular solves
    zb = jnp.broadcast_to(z_obs.astype(l.dtype),
                          l.shape[:-2] + z_obs.shape[-1:])
    w = solve_triangular(l, zb[..., None], lower=True)       # (..., n, 1)
    v = solve_triangular(l, jnp.swapaxes(sigma_no.astype(l.dtype), -1, -2),
                         lower=True)
    mu = (jnp.swapaxes(v, -1, -2) @ w)[..., 0]               # (..., m)
    if sigma_nn_diag is None:
        return mu
    var = sigma_nn_diag - jnp.sum(v * v, axis=-2)
    return mu, var


def krige(locs_obs, z_obs, locs_new, theta, policy: PrecisionPolicy, *,
          nb: int = 128, nu_static=None, metric="euclidean", nugget=0.0,
          jitter=1e-6, use_tiles=None, return_var: bool = False):
    """Kriging mean (and optionally variance) at locs_new.

    theta may be a single (3,) vector or a stacked (..., 3) batch of
    candidates; the mean (and variance) then carry the same leading axes
    (one mixed-precision factorization per candidate).  `nugget` is added
    to Sigma_oo's diagonal only (never the cross covariance), matching the
    likelihood's observation model.  `use_tiles` overrides the tiled/dense
    factor choice exactly like `make_loglik`'s flag (None = auto).
    """
    theta = jnp.asarray(theta)
    if policy.mode == "dst":
        # DST has no kriging variant; predict densely in hi precision (the
        # same convention the batch engine documents)
        policy, use_tiles = PrecisionPolicy.full(policy.hi), None
    # Sigma_oo is built and factored by THE shared covariance/factor-path
    # selection (make_factor_fn), so kriging can never pick a different
    # precision path than the likelihood for the same policy
    factor = make_factor_fn(locs_obs, policy, nb=nb, nu_static=nu_static,
                            metric=metric, nugget=nugget, jitter=jitter,
                            use_tiles=use_tiles)
    l = factor(theta)
    sigma_no = matern_covariance(locs_new, locs_obs, theta, nu_static=nu_static,
                                 metric=metric).astype(policy.hi)
    if not return_var:
        return krige_from_factor(l, z_obs, sigma_no)
    sigma_nn_diag = theta[..., 0:1] * jnp.ones(locs_new.shape[0], dtype=policy.hi)
    return krige_from_factor(l, z_obs, sigma_no, sigma_nn_diag=sigma_nn_diag)


def pmse(mu, y_true):
    """Mean squared prediction error; batched over leading axes of mu."""
    mu = jnp.asarray(mu)
    y_true = jnp.asarray(y_true).astype(mu.dtype)
    return jnp.mean((mu - y_true) ** 2, axis=-1)


def krige_pmse(locs_obs, z_obs, locs_new, y_true, theta,
               policy: PrecisionPolicy, *, nb: int = 128, nu_static=None,
               metric="euclidean", nugget=0.0, jitter=1e-6, use_tiles=None):
    """PMSE of the kriging predictor at locs_new against held-out y_true.

    Batched over leading axes of theta; this is the per-candidate scoring
    function the batch engine vmaps.
    """
    mu = krige(locs_obs, z_obs, locs_new, theta, policy, nb=nb,
               nu_static=nu_static, metric=metric, nugget=nugget,
               jitter=jitter, use_tiles=use_tiles)
    return pmse(mu, y_true)


def kfold_pmse(locs, z, theta, policy: PrecisionPolicy, *, k: int = 10,
               nb: int = 128, nu_static=None, metric="euclidean", seed: int = 0):
    """k-fold cross-validated PMSE (paper uses k=10).

    Folds must keep n_obs a multiple of nb for the tile path; we trim the
    remainder into the observation set rather than dropping data.
    """
    n = locs.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    fold_size = n // k
    scores = []
    for f in range(k):
        test_idx = perm[f * fold_size:(f + 1) * fold_size]
        train_mask = np.ones(n, dtype=bool)
        train_mask[test_idx] = False
        train_idx = np.nonzero(train_mask)[0]
        # trim training set to a tile multiple (move extras to test side? no:
        # just drop up to nb-1 points -- harmless for PMSE estimation)
        m = (len(train_idx) // nb) * nb
        if m == 0:
            raise ValueError("fold too small for tile size")
        tr = train_idx[:m]
        mu = krige(locs[tr], z[tr], locs[test_idx], theta, policy,
                   nb=nb, nu_static=nu_static, metric=metric)
        scores.append(float(pmse(mu, z[test_idx])))
    return float(np.mean(scores)), scores
