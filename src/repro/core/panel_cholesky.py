"""TPU-native mixed-precision panel Cholesky (the performance path).

This is the hardware adaptation of paper Algorithm 1 (see DESIGN.md §3):
instead of a StarPU task DAG over heterogeneous tiles, the factorization is
restructured into p statically-shaped, trace-time-unrolled panel steps over
a *split storage*:

  band : (p, t, nb, nb) in hi dtype -- band[i, d] = tile (i, i-d), i.e. the
         diag_thick tile sub-diagonals the paper keeps in double precision;
  off  : (p, p, nb, nb) in lo dtype -- tiles with i - j >= t (lower
         triangle), i.e. the single-precision region.  Storing these in lo
         is the TPU analogue of the paper keeping SP copies in the spare
         triangle: it halves their HBM/ICI bytes.

Per step k (all slices static because the loop is unrolled):
  1. potrf(band[k,0]) in hi                               (dpotrf)
  2. hi TRSM on the <= t-1 band panel tiles               (dtrsm)
     lo TRSM on the off panel tiles                       (strsm)
  3. hi batched sub-diagonal updates d = 0..t-1           (dsyrk/dgemm)
  4. one big lo GEMM U = C_lo C_lo^T applied to the off-band region
     under a static tile mask                             (sgemm)

Step 4 computes the full (m x m) square -- ~2x the FLOPs of the needed
lower trapezoid.  That waste is deliberate v1 behaviour: it is the first
hypothesis of the §Perf hillclimb (see EXPERIMENTS.md), fixed by the
column-chunked variant `off_update="chunked"`.

Everything is jnp (differentiable, GSPMD-shardable).  Numerics match the
faithful tile engine (tests assert allclose against tile_cholesky.py).
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from .. import obs
from ..covariance.matern import matern_covariance
from .precision import PrecisionPolicy, lo_matmul


# ----------------------------------------------------------------------
# banded storage construction
# ----------------------------------------------------------------------

def build_banded_covariance(locs, theta, *, nb: int, policy: PrecisionPolicy,
                            nu_static=None, metric="euclidean", jitter=1e-6):
    """Matern covariance directly into (band, off) split storage.

    band[i, d] = Sigma tile (i, i-d) in hi; off[i, j] = tile (i, j) in lo
    (only i - j >= t is meaningful; the rest is zero).
    """
    locs = jnp.asarray(locs)
    n = locs.shape[0]
    assert n % nb == 0
    p = n // nb
    t = min(policy.diag_thick, p)
    hi, lo = policy.hi, (policy.lo if policy.mode != "full" else policy.hi)

    locs_t = locs.reshape(p, nb, locs.shape[-1])

    def tile_cov(la, lb):
        return matern_covariance(la, lb, theta, nu_static=nu_static, metric=metric)

    pair_cov = jax.vmap(tile_cov)

    # band sub-diagonals
    band_cols = []
    for d in range(t):
        blk = pair_cov(locs_t[d:], locs_t[:p - d]).astype(hi)   # (p-d, nb, nb)
        if d > 0:
            blk = jnp.concatenate(
                [jnp.zeros((d, nb, nb), dtype=hi), blk], axis=0)
        band_cols.append(blk)
    band = jnp.stack(band_cols, axis=1)                          # (p, t, nb, nb)
    eye = jnp.eye(nb, dtype=hi) * jitter
    band = band.at[:, 0].add(eye[None])

    # off-band tiles (full p x p grid; only i-j >= t used downstream)
    off = jax.vmap(lambda la: pair_cov(
        jnp.broadcast_to(la[None], (p,) + la.shape), locs_t))(locs_t)
    ii, jj = np.meshgrid(np.arange(p), np.arange(p), indexing="ij")
    off_mask = jnp.asarray((ii - jj) >= t)[:, :, None, None]
    off = jnp.where(off_mask, off, 0.0).astype(lo)               # (p, p, nb, nb)
    return band, off


def assemble_from_banded(band, off, t: int, dtype=None):
    """(band, off) -> dense lower-triangular (n, n) matrix in hi."""
    p, _, nb, _ = band.shape
    dtype = dtype or band.dtype
    n = p * nb
    out = jnp.zeros((n, n), dtype=dtype)
    for i in range(p):
        for d in range(min(i + 1, t)):
            j = i - d
            out = out.at[i * nb:(i + 1) * nb, j * nb:(j + 1) * nb].set(
                band[i, d].astype(dtype))
        for j in range(0, i - t + 1):
            out = out.at[i * nb:(i + 1) * nb, j * nb:(j + 1) * nb].set(
                off[i, j].astype(dtype))
    tri = jnp.tril(jnp.ones((n, n), dtype=bool))
    return jnp.where(tri, out, jnp.zeros((), dtype=dtype))


# ----------------------------------------------------------------------
# the factorization
# ----------------------------------------------------------------------

def _batched_trsm_right_lt(l, a, exec_dtype, out_dtype):
    """a[i] <- a[i] L^{-T} for a: (m, nb, nb)."""
    l = l.astype(exec_dtype)
    a = a.astype(exec_dtype)
    l = jnp.broadcast_to(l, a.shape[:-2] + l.shape[-2:])
    x = solve_triangular(l, jnp.swapaxes(a, -1, -2), lower=True)
    return jnp.swapaxes(x, -1, -2).astype(out_dtype)


def panel_cholesky_banded(band, off, policy: PrecisionPolicy, *,
                          off_update: str = "square"):
    """Factor the banded-storage SPD matrix in place. Returns (band, off).

    off_update: "square"  -- one full m x m lo GEMM per step (v1; ~2x lo
                             FLOP waste, exercised by the perf hillclimb);
                "chunked" -- per-column-block lo GEMMs over the lower
                             trapezoid only (near-exact FLOPs).
    """
    # dispatch-boundary telemetry: no-op when disabled or when `band` is a
    # tracer (the BatchEngine panel path jits/vmaps this whole function)
    with obs.maybe_span("core.panel_cholesky", band,
                        p=band.shape[0], nb=band.shape[-1],
                        off_update=off_update) as sp:
        band, off = _panel_cholesky_banded(band, off, policy,
                                           off_update=off_update)
        if sp is not obs.NULL_SPAN:
            band.block_until_ready()
            off.block_until_ready()
        return band, off


def _panel_cholesky_banded(band, off, policy: PrecisionPolicy, *,
                           off_update: str):
    p, t, nb, _ = band.shape
    hi = policy.hi
    lo = off.dtype

    for k in range(p):
        lkk = jnp.linalg.cholesky(band[k, 0])
        band = band.at[k, 0].set(lkk)
        lkk_lo = lkk.astype(lo)

        m_t = p - k - 1
        if m_t == 0:
            break

        # --- panel TRSMs -------------------------------------------------
        n_band_panel = min(t - 1, m_t)
        for d in range(1, n_band_panel + 1):          # dtrsm (hi), tiles (k+d, k)
            upd = _batched_trsm_right_lt(lkk, band[k + d, d][None], hi, hi)[0]
            band = band.at[k + d, d].set(upd)
        if k + t <= p - 1:                            # strsm (lo)
            sol = _batched_trsm_right_lt(lkk_lo, off[k + t:, k],
                                         policy.solve_dtype, lo)
            off = off.at[k + t:, k].set(sol)

        # --- gather the factored panel column as hi tiles ----------------
        parts = [band[k + d, d][None] for d in range(1, n_band_panel + 1)]
        if k + t <= p - 1:
            parts.append(off[k + t:, k].astype(hi))
        c_hi = jnp.concatenate(parts, axis=0)
        # c_hi[m] = tile (k+1+m, k), shape (m_t, nb, nb)

        # --- hi band updates: sub-diagonals d = 0..t-1 (dsyrk/dgemm) -----
        for d in range(0, min(t, m_t)):
            lhs = c_hi[d:]
            rhs = c_hi[:m_t - d]
            upd = jnp.einsum("iab,icb->iac", lhs, rhs,
                             preferred_element_type=hi)
            band = band.at[k + 1 + d:, d].add(-upd.astype(hi))

        # --- lo off-band update (sgemm) ----------------------------------
        c_lo = c_hi.astype(lo).reshape(m_t * nb, nb)
        ii, jj = np.meshgrid(np.arange(k + 1, p), np.arange(k + 1, p),
                             indexing="ij")
        mask = jnp.asarray((ii - jj) >= t)[:, :, None, None]
        if off_update == "square":
            u = lo_matmul(c_lo, c_lo.T, policy)                  # (m, m) lo
            u_t = u.reshape(m_t, nb, m_t, nb).transpose(0, 2, 1, 3)
            blk = off[k + 1:, k + 1:]
            off = off.at[k + 1:, k + 1:].set(
                jnp.where(mask, (blk - u_t.astype(lo)), blk))
        elif off_update == "chunked":
            # exact lower trapezoid: for each target column-tile j, only
            # rows i >= j + t receive the lo update.
            c_lo_t = c_lo.reshape(m_t, nb, nb)
            for j in range(k + 1, p - t):
                rows = slice(j + t, p)                  # global tile rows
                lhs = c_lo_t[j + t - k - 1:]            # tiles (j+t..p-1, k)
                rhs = c_lo_t[j - k - 1]                 # tile (j, k)
                upd = lo_matmul(lhs, jnp.broadcast_to(rhs.T[None],
                                                      (lhs.shape[0], nb, nb)),
                                policy)
                off = off.at[rows, j].add(-upd.astype(lo))
        else:
            raise ValueError(off_update)
    return band, off


# ----------------------------------------------------------------------
# solve / likelihood on banded storage
# ----------------------------------------------------------------------

def banded_forward_solve(band, off, z, t: int):
    """w = L^{-1} z via blocked forward substitution on split storage."""
    p, _, nb, _ = band.shape
    hi = band.dtype
    z_t = z.astype(hi).reshape(p, nb)
    ws = []
    for i in range(p):
        acc = z_t[i]
        for d in range(1, min(i + 1, t)):
            acc = acc - band[i, d] @ ws[i - d]
        if i - t >= 0:
            w_mat = jnp.stack(ws[:i - t + 1])            # (i-t+1, nb)
            acc = acc - jnp.einsum("jab,jb->a", off[i, :i - t + 1].astype(hi),
                                   w_mat)
        ws.append(solve_triangular(band[i, 0], acc, lower=True))
    return jnp.concatenate(ws)


def banded_loglik(band, off, z, t: int):
    """Gaussian log-likelihood (Eq. 2) from the factored banded storage."""
    p, _, nb, _ = band.shape
    n = p * nb
    diag = jnp.stack([jnp.diagonal(band[i, 0]) for i in range(p)])
    logdet_half = jnp.sum(jnp.log(diag))
    w = banded_forward_solve(band, off, z, t)
    return (-0.5 * n * jnp.log(2.0 * jnp.pi) - logdet_half
            - 0.5 * jnp.sum(w * w))


def geostat_loglik_step(locs, z, theta, *, nb: int, policy: PrecisionPolicy,
                        nu_static=None, metric="euclidean", jitter=1e-6,
                        off_update: str = "square"):
    """One full likelihood evaluation: cov-gen -> factor -> solve -> ll.

    This is the unit the paper benchmarks ("time per iteration") and the
    function the geostat dry-run lowers on the production mesh.
    """
    with obs.maybe_span("core.panel_loglik_step", locs, theta,
                        n=locs.shape[0] if hasattr(locs, "shape") else None,
                        nb=nb, mode=policy.mode):
        band, off = build_banded_covariance(locs, theta, nb=nb, policy=policy,
                                            nu_static=nu_static,
                                            metric=metric, jitter=jitter)
        t = min(policy.diag_thick, band.shape[0])
        band, off = panel_cholesky_banded(band, off, policy,
                                          off_update=off_update)
        return banded_loglik(band, off, z, t)
