"""Distributed mixed-precision panel Cholesky for the production mesh.

The banded-storage engine (panel_cholesky.py) is exact but its per-step
slices shrink by one tile per step -- GSPMD cannot keep shrinking,
misaligned slices sharded, so at n=512k it replicated the trailing matrix
(3.3 TB/chip, dry-run iteration 0).  This module reformulates the sweep
for SPMD:

  storage   : off  (n, n) lo dtype, sharded P("data", "model")
              band (p, t, nb, nb) hi dtype (the paper's DP band)
  per step k (unrolled, all shapes STATIC and mesh-aligned):
    potrf/band-TRSM on hi tiles (small gathers);
    lo TRSM on the FULL masked panel column  (row-masked, P("data"));
    hi sub-diagonal updates (exact, tiny);
    lo trailing update U = C C^T over the FULL matrix, applied under the
    trailing+off-band mask, sharded P("data", "model").

Full-width masked updates cost ~3x the useful n^3/3 FLOPs (every step
touches the whole matrix).  That is the *baseline* the §Perf hillclimb
attacks: `version="aligned"` shrinks the row range to the 16-tile-aligned
boundary (static per step, still shard-aligned), cutting the waste to
~1.5x; column pruning (v3) gets ~1.15x.  See EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from ..models.sharding import constrain
from .precision import PrecisionPolicy, lo_matmul

_GEO_RULES_NOTE = """Logical axes used here (models/sharding.DEFAULT_RULES):
rows of the matrix -> "data", cols -> "model"."""


def _c_rows(x):
    return constrain(x, "geo_rows .")


def _c_mat(x):
    return constrain(x, "geo_rows geo_cols")


def build_covariance_distributed(locs, theta, *, nb: int,
                                 policy: PrecisionPolicy, nu_static=0.5,
                                 jitter: float = 1e-6):
    """(off (n,n) lo sharded, band (p,t,nb,nb) hi) from the Matern kernel.

    Distances use the MXU form |a|^2+|b|^2-2ab^T: one (n,2)x(2,n) matmul
    shards over the mesh; no (n,n,2) intermediate exists.
    """
    n = locs.shape[0]
    p = n // nb
    t = min(policy.diag_thick, p)
    hi = policy.hi
    lo = policy.lo if policy.mode != "full" else policy.hi
    theta1, theta2 = theta[0], theta[1]

    locs_hi = locs.astype(hi)  # coord precision follows the band tier

    def _corr(r):
        x = r / theta2
        if nu_static == 0.5:
            c = jnp.exp(-x)
        elif nu_static == 1.5:
            c = (1.0 + x) * jnp.exp(-x)
        elif nu_static == 2.5:
            c = (1.0 + x + x * x / 3.0) * jnp.exp(-x)
        else:
            raise ValueError("distributed cov-gen uses half-integer nu")
        return theta1 * jnp.where(r == 0.0, 1.0, c)

    norms = jnp.sum(locs_hi * locs_hi, axis=-1)
    cross = _c_mat(locs_hi @ locs_hi.T)
    d2 = jnp.maximum(norms[:, None] + norms[None, :] - 2.0 * cross, 0.0)
    cov = _corr(jnp.sqrt(d2))

    # off-band lower storage: band region + upper triangle zeroed so the
    # solve can use unmasked column matvecs
    ii = jnp.repeat(jnp.arange(p), nb)
    off_mask = (ii[:, None] - ii[None, :]) >= t
    off = _c_mat(jnp.where(off_mask, cov, 0.0).astype(lo))

    # hi band tiles built DIRECTLY from locations (slicing the sharded
    # (n, n) cov into 512 tiles gathered ~137 GB replicated stacks --
    # dry-run iteration D9b); the vmapped per-diagonal build stays local
    locs_t = locs_hi.reshape(p, nb, 2)

    def tile_cov(la, lb):
        dd = jnp.maximum(
            jnp.sum(la * la, -1)[:, None] + jnp.sum(lb * lb, -1)[None, :]
            - 2.0 * (la @ lb.T), 0.0)
        return _corr(jnp.sqrt(dd))

    band_cols = []
    for d in range(t):
        blk = jax.vmap(tile_cov)(locs_t[d:], locs_t[:p - d]).astype(hi)
        if d > 0:
            blk = jnp.concatenate(
                [jnp.zeros((d, nb, nb), hi), blk], axis=0)
        band_cols.append(blk)
    band = jnp.stack(band_cols, axis=1)
    band = band.at[:, 0].add(jitter * jnp.eye(nb, dtype=hi)[None])
    # shard the band storage: rows over data, tile rows over model
    band = constrain(band, "geo_rows . geo_cols .")
    return off, band


def panel_cholesky_distributed(off, band, policy: PrecisionPolicy, *,
                               version: str = "masked_full",
                               align: int = 16):
    """Factor in place; returns (off, band) with L in the same layout.

    version:
      masked_full : p unrolled full-width masked steps (v1; ~3x FLOP waste)
      aligned     : rows pruned to 16-tile-aligned boundaries (~1.5x waste;
                    shapes differ per step => must stay unrolled)
      fori        : masked_full inside ONE lax.fori_loop body -- identical
                    numerics/FLOPs, but the (off, band) carry is buffer-
                    aliased so peak memory stops scaling with p, and the
                    compile is one body instead of p (§Perf G5)
    """
    if version == "fori":
        return _panel_cholesky_fori(off, band, policy)
    p, t, nb, _ = band.shape
    n = p * nb
    hi = policy.hi
    lo = off.dtype
    row_tile = np.arange(p)

    for k in range(p):
        lkk = jnp.linalg.cholesky(band[k, 0])
        band = band.at[k, 0].set(lkk)
        lkk_lo = lkk.astype(lo)
        m_t = p - k - 1
        if m_t == 0:
            break

        # hi band-panel TRSMs (exact tiles)
        n_band_panel = min(t - 1, m_t)
        for d in range(1, n_band_panel + 1):
            upd = solve_triangular(lkk, band[k + d, d].T, lower=True).T
            band = band.at[k + d, d].set(upd)

        # lo panel TRSM over the full masked column (rows >= k+t)
        col = _c_rows(off[:, k * nb:(k + 1) * nb].astype(policy.solve_dtype))
        sol = solve_triangular(lkk_lo.astype(policy.solve_dtype), col.T,
                               lower=True).T
        row_mask = jnp.repeat(row_tile >= k + t, nb)[:, None]
        col_new = jnp.where(row_mask, sol, col).astype(lo)
        off = off.at[:, k * nb:(k + 1) * nb].set(_c_rows(col_new))

        # assemble the full panel column in lo: band rows + off rows
        c_band_rows = []
        for d in range(1, n_band_panel + 1):
            c_band_rows.append(((k + d), band[k + d, d].astype(lo)))
        c_lo = jnp.where(row_mask, col_new, 0.0)
        for idx, tile in c_band_rows:
            c_lo = c_lo.at[idx * nb:(idx + 1) * nb].set(tile)
        c_lo = _c_rows(c_lo)                       # (n, nb), rows <= k zero

        # hi sub-diagonal updates (dsyrk/dgemm band), ROLL-aligned: slicing
        # c_t at (k+d)-offsets is mesh-misaligned and made GSPMD gather
        # 17 GiB operands per (k,d) pair (iteration D9b); jnp.roll keeps
        # every operand full-width and sharded.  c_t rows <= k are zero, so
        # sub-k products vanish on their own; only roll wraparound needs a
        # mask.
        c_t = c_lo.reshape(p, nb, nb).astype(hi)
        c_t = constrain(c_t, "geo_rows geo_cols .")
        for d in range(0, min(t, m_t)):
            shifted = jnp.roll(c_t, d, axis=0) if d else c_t
            upd = jnp.einsum("iab,icb->iac", c_t, shifted,
                             preferred_element_type=hi)
            wrap_ok = (np.arange(p) >= d)[:, None, None]
            band = band.at[:, d].add(-jnp.where(wrap_ok, upd, 0.0))

        # lo off-band trailing update, full-width masked (v1) or row-aligned
        if version == "aligned":
            start_tile = ((k + 1 + align - 1) // align) * align
            start = min(start_tile * nb, n)
            u_rows = c_lo[start:]
            fr_lo = max(start - align * nb, 0)
            fringe = c_lo[fr_lo:start] if start > 0 else c_lo[:0]
            pieces = []
            if fringe.shape[0]:
                pieces.append((fr_lo, fringe))
            if u_rows.shape[0]:
                pieces.append((start, u_rows))
        else:
            pieces = [(0, c_lo)]
        for row0, c_rows in pieces:
            if c_rows.shape[0] == 0:
                continue
            u = lo_matmul(c_rows, c_lo.T, policy)  # (rows, n)
            u = constrain(u, "geo_rows geo_cols")
            rows_idx = row_tile[row0 // nb: row0 // nb + c_rows.shape[0] // nb]
            ii = jnp.repeat(jnp.asarray(rows_idx), nb)[:, None]
            jj = jnp.repeat(row_tile, nb)[None, :]
            mask = (ii - jj >= t) & (jj > k) & (ii > k)
            blk = off[row0:row0 + c_rows.shape[0]]
            off = off.at[row0:row0 + c_rows.shape[0]].set(
                jnp.where(mask, (blk - u.astype(lo)), blk))
    return off, band


def _c_r2(x):
    # fori-path sharding: rows 2-D (data x model), cols unsharded --
    # traced-offset column slices cannot cross a sharded dim
    return constrain(x, "geo_rows2d .")


def _panel_cholesky_fori(off, band, policy: PrecisionPolicy):
    """masked_full sweep as a single fori_loop body (all shapes static in
    k; masks/slices use the traced k).  See panel_cholesky_distributed."""
    p, t, nb, _ = band.shape
    n = p * nb
    hi = policy.hi
    lo = off.dtype
    row_tile = jnp.arange(p)
    ii = jnp.repeat(row_tile, nb)
    off = _c_r2(off)

    def step(k, carry):
        off, band = carry
        lkk = jnp.linalg.cholesky(band[k, 0])
        band = band.at[k, 0].set(lkk)
        lkk_lo = lkk.astype(lo)

        # hi band-panel TRSMs (traced index, clamped + validity-masked)
        for d in range(1, t):
            idx = jnp.minimum(k + d, p - 1)
            tile = band[idx, d]
            upd = solve_triangular(lkk, tile.T, lower=True).T
            valid = (k + d) < p
            band = band.at[idx, d].set(jnp.where(valid, upd, tile))

        # lo panel TRSM over the full masked column
        col = jax.lax.dynamic_slice(off, (0, k * nb), (n, nb))
        col = _c_r2(col.astype(policy.solve_dtype))
        sol = solve_triangular(lkk_lo.astype(policy.solve_dtype), col.T,
                               lower=True).T
        row_mask = (ii >= k + t)[:, None]
        col_new = jnp.where(row_mask, sol, col).astype(lo)
        off = jax.lax.dynamic_update_slice(off, _c_r2(col_new), (0, k * nb))

        # assemble panel column: off rows (>= k+t) + hi band rows
        c_lo = jnp.where(row_mask, col_new, 0.0)
        for d in range(1, t):
            idx = jnp.minimum(k + d, p - 1)
            cur = jax.lax.dynamic_slice(c_lo, (idx * nb, 0), (nb, nb))
            tile = jnp.where((k + d) < p, band[idx, d].astype(lo), cur)
            c_lo = jax.lax.dynamic_update_slice(c_lo, tile, (idx * nb, 0))
        c_lo = _c_r2(c_lo)                       # rows <= k are zero

        # hi sub-diagonal updates, roll-aligned (see unrolled variant)
        c_t = constrain(c_lo.reshape(p, nb, nb).astype(hi),
                        "geo_rows geo_cols .")
        for d in range(t):
            shifted = jnp.roll(c_t, d, axis=0) if d else c_t
            upd = jnp.einsum("iab,icb->iac", c_t, shifted,
                             preferred_element_type=hi)
            wrap_ok = (row_tile >= d)[:, None, None]
            band = band.at[:, d].add(-jnp.where(wrap_ok, upd, 0.0))

        # lo off-band trailing update, full-width masked
        u = lo_matmul(c_lo, c_lo.T, policy)
        u = _c_r2(u)
        mask = ((ii[:, None] - ii[None, :] >= t)
                & (ii[None, :] > k) & (ii[:, None] > k))
        off = _c_r2(jnp.where(mask, (off - u.astype(lo)), off))
        return off, band

    return jax.lax.fori_loop(0, p, step, (off, band))


def loglik_distributed(off, band, z, t: int):
    """Blocked forward solve + logdet on the distributed layout.

    COLUMN-wise substitution: after solving block j, its contribution is
    pushed into the running residual with one (n, nb) column matvec --
    column slices keep their row sharding, unlike the row-strip variant
    whose per-step (nb, j*nb) gathers summed to ~256 GB/chip at n=512k
    (dry-run iteration 2).  fori_loop body: the unrolled variant kept
    p live copies of the (n, nb) fp32 columns (§Perf G5)."""
    p, _, nb, _ = band.shape
    n = p * nb
    hi = band.dtype
    off = _c_r2(off)   # traced col slices below: cols must stay unsharded

    def step(j, carry):
        acc, w, logdet = carry
        rhs = jax.lax.dynamic_slice(acc, (j * nb, 0), (nb, 1))[:, 0]
        for d in range(1, t):
            idx = jnp.maximum(j - d, 0)
            wd = jax.lax.dynamic_slice(w, (idx * nb,), (nb,))
            contrib = band[j, d] @ wd
            rhs = rhs - jnp.where((j - d) >= 0, contrib, 0.0)
        ljj = band[j, 0]
        w_j = solve_triangular(ljj, rhs, lower=True)
        w = jax.lax.dynamic_update_slice(w, w_j, (j * nb,))
        logdet = logdet + jnp.sum(jnp.log(jnp.diagonal(ljj)))
        col = jax.lax.dynamic_slice(off, (0, j * nb), (n, nb)).astype(hi)
        acc = _c_r2(acc - col @ w_j[:, None])     # band rows of col are 0
        return acc, w, logdet

    acc0 = _c_r2(z.astype(hi)[:, None])
    _, w, logdet = jax.lax.fori_loop(
        0, p, step, (acc0, jnp.zeros((n,), hi), jnp.zeros((), hi)))
    return (-0.5 * n * jnp.log(2.0 * jnp.pi) - logdet
            - 0.5 * jnp.sum(w * w))


def geostat_loglik_distributed(locs, z, theta, *, nb: int,
                               policy: PrecisionPolicy, nu_static=0.5,
                               version: str = "masked_full"):
    """One full MLE likelihood evaluation, SPMD-shardable end to end."""
    off, band = build_covariance_distributed(locs, theta, nb=nb,
                                             policy=policy,
                                             nu_static=nu_static)
    t = min(policy.diag_thick, band.shape[0])
    off, band = panel_cholesky_distributed(off, band, policy,
                                           version=version)
    return loglik_distributed(off, band, z, t)
