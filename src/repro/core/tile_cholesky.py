"""Mixed-precision tile Cholesky factorization -- paper Algorithm 1, faithful.

This module is the *numerical reference* implementation: a tile-by-tile,
trace-time-unrolled right-looking Cholesky in which every tile op runs in the
dtype Algorithm 1 prescribes:

  line  8  dpotrf   : diagonal tile, hi precision
  line  9  dlag2s   : hi->lo copy of the factored diagonal tile (tmp)
  line 12  dtrsm    : panel tile inside the band, hi
  line 14  strsm    : panel tile outside the band, lo (using the lo tmp tile)
  line 15  sconv2d  : lo->hi refresh of the hi copy (needed by dsyrk)
  line 19  dsyrk    : diagonal-tile update, ALWAYS hi (operands upcast)
  line 25  dgemm    : in-band trailing tile, hi
  line 27  sgemm    : off-band trailing tile, lo math AND lo storage
                      (off-band accumulation error compounds in lo exactly
                      as in the paper, where SP tiles live in the spare
                      triangle of the symmetric matrix)

Off-band tiles are *stored* in `policy.lo`; band tiles in `policy.hi`.
Unrolling is fine for the statistical studies (p <= ~40 tiles).  The
performance/distributed path lives in panel_cholesky.py.

Also implements the DST (Diagonal-Super-Tile / independent blocks)
covariance-tapering baseline of paper Sec. V-B.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from .. import obs
from .precision import PrecisionPolicy, lo_matmul


def _potrf(a, dtype):
    return jnp.linalg.cholesky(a.astype(dtype))


def _trsm_right_lt(l_kk, a_ik, exec_dtype, out_dtype):
    """A_ik <- A_ik * L_kk^{-T} executed in exec_dtype, stored as out_dtype."""
    l = l_kk.astype(exec_dtype)
    a = a_ik.astype(exec_dtype)
    x = solve_triangular(l, jnp.swapaxes(a, -1, -2), lower=True, trans=0)
    return jnp.swapaxes(x, -1, -2).astype(out_dtype)


def split_tiles(a, nb: int):
    """(..., n, n) -> dict[(i, j)] -> (..., nb, nb) lower-triangle tiles.

    Leading axes of `a` are treated as a batch of matrices.
    """
    n = a.shape[-1]
    assert n % nb == 0, f"n={n} must be a multiple of nb={nb}"
    p = n // nb
    return {
        (i, j): a[..., i * nb:(i + 1) * nb, j * nb:(j + 1) * nb]
        for i in range(p) for j in range(i + 1)
    }, p


def assemble_lower(tiles, p: int, nb: int, dtype):
    """Lower-triangle tiles -> full (..., n, n) lower-triangular matrix."""
    n = p * nb
    batch = tiles[(0, 0)].shape[:-2]
    out = jnp.zeros(batch + (n, n), dtype=dtype)
    for (i, j), t in tiles.items():
        out = out.at[..., i * nb:(i + 1) * nb, j * nb:(j + 1) * nb].set(
            t.astype(dtype))
    tri = jnp.tril(jnp.ones((n, n), dtype=bool))
    return jnp.where(tri, out, jnp.zeros((), dtype=dtype))


def tile_cholesky(a, nb: int, policy: PrecisionPolicy, *, schedule=None):
    """Factor SPD `a` (..., n, n) -> lower-triangular L in policy.hi dtype.

    Faithful Algorithm 1.  For mode="full" every tile is hi (reference DP
    path).  For mode="dst" use dst_cholesky instead.  Leading axes of `a`
    are a batch of independent factorizations (one per candidate theta);
    every tile op below batches over them.

    `schedule` opts into the dynamic task runtime (DESIGN.md §12): pass a
    `repro.sched.SchedConfig` and the same task DAG executes out of order
    on a threaded worker pool, bitwise-identical to the sequential loop
    nest below.  Eager-only (the runtime is host-side Python) -- leave it
    None inside jit/vmap.
    """
    if policy.mode == "dst":
        raise ValueError("use dst_cholesky for the DST baseline")
    if schedule is not None:
        from ..sched.runtime import scheduled_tile_cholesky
        l, _report = scheduled_tile_cholesky(a, nb, policy, schedule)
        return l
    # telemetry at the dispatch boundary only: under jit/vmap `a` is a
    # tracer and maybe_span degrades to the no-op (DESIGN.md §13)
    with obs.maybe_span("core.tile_cholesky", a, n=a.shape[-1], nb=nb,
                        mode=policy.mode) as sp:
        l = _tile_cholesky_eager(a, nb, policy)
        if sp is not obs.NULL_SPAN:
            l.block_until_ready()   # time the math, not the async dispatch
        return l


def _tile_cholesky_eager(a, nb: int, policy: PrecisionPolicy):
    hi, lo = policy.hi, policy.lo
    tiles, p = split_tiles(a, nb)

    def tier(i, j):
        d = abs(i - j)
        if policy.mode == "three_tier" and d >= policy.diag_thick2:
            return policy.lo2
        return lo

    # initial storage conversion (lines 2-6, dlag2s on off-band tiles)
    store = {}
    for (i, j), t in tiles.items():
        store[(i, j)] = t.astype(hi) if policy.in_band(i, j) else t.astype(tier(i, j))

    for k in range(p):
        l_kk = _potrf(store[(k, k)], hi)          # line 8: dpotrf
        store[(k, k)] = l_kk
        l_kk_lo = l_kk.astype(lo)                 # line 9: dlag2s -> tmp

        for i in range(k + 1, p):                 # panel TRSMs
            if policy.in_band(i, k):              # line 12: dtrsm
                store[(i, k)] = _trsm_right_lt(l_kk, store[(i, k)], hi, hi)
            else:                                 # line 14: strsm (+15 sconv2d)
                t = tier(i, k)
                store[(i, k)] = _trsm_right_lt(
                    l_kk_lo, store[(i, k)].astype(lo), policy.solve_dtype, t)

        for j in range(k + 1, p):                 # trailing update
            a_jk_hi = store[(j, k)].astype(hi)    # sconv2d'd copy if off-band
            a_jk_hi_t = jnp.swapaxes(a_jk_hi, -1, -2)
            # line 19: dsyrk, always hi
            store[(j, j)] = store[(j, j)] - a_jk_hi @ a_jk_hi_t
            for i in range(j + 1, p):
                if policy.in_band(i, j):          # line 25: dgemm
                    a_ik = store[(i, k)].astype(hi)
                    store[(i, j)] = store[(i, j)] - a_ik @ a_jk_hi_t
                else:                             # line 27: sgemm (lo storage)
                    t = tier(i, j)
                    upd = lo_matmul(store[(i, k)], jnp.swapaxes(store[(j, k)], -1, -2),
                                    policy, tier=lo)
                    store[(i, j)] = (store[(i, j)].astype(lo) - upd).astype(t)

    return assemble_lower(store, p, nb, hi)


def dst_cholesky(a, nb: int, diag_thick: int, hi=jnp.float32):
    """DST / independent-blocks baseline (paper Sec. V-B, Fig. 1b).

    The matrix is replaced by its block-diagonal of "super-tiles" of
    diag_thick x diag_thick tiles (off-super-tile entries = zero), and each
    independent block is factored in full precision.  Returns the list of
    per-block factors plus the block slices (the block-diagonal factor).
    Leading axes of `a` batch over independent matrices.
    """
    n = a.shape[-1]
    assert n % nb == 0
    super_nb = diag_thick * nb
    blocks = []
    start = 0
    while start < n:
        stop = min(start + super_nb, n)
        blk = a[..., start:stop, start:stop].astype(hi)
        blocks.append((slice(start, stop), jnp.linalg.cholesky(blk)))
        start = stop
    return blocks


def dst_assemble(blocks, n: int, dtype=jnp.float32):
    """Assemble the block-diagonal factor into a dense (n, n) matrix."""
    out = jnp.zeros((n, n), dtype=dtype)
    for sl, l in blocks:
        out = out.at[sl, sl].set(l.astype(dtype))
    return out


def reference_cholesky(a, hi=jnp.float32):
    """Plain dense Cholesky in hi precision (DP(100%) reference)."""
    return jnp.linalg.cholesky(a.astype(hi))
