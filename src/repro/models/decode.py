"""Serving: cache construction, prefill, and single-token decode.

Cache layout: one pytree entry per block slot in the cycle pattern, each
stacked over cycles (leading axis n_cycles) so decode lax.scans over
(cycle_params, cycle_cache) together:

  attn (full) : {k, v: (C, B, S_max, KV, hd)}           (rope'd at write)
  attn (SWA)  : {k, v: (C, B, W, KV, hd), pos: (C, W)}  (circular)
  mamba       : {conv: (C, B, K-1, d_in), ssm: (C, B, d_in, N)}
  mlstm       : {c: (C,B,H,hd,hd), n: (C,B,H,hd), m: (C,B,H)}
  slstm       : {c, n, h, m: (C, B, H, hd)}
  whisper     : decoder self cache + cross {k, v: (C, B, F, KV, hd)}

The banded-precision KV option (paper technique -> LM serving, DESIGN.md
§9) stores the cache bf16 and, through the mp_attention kernel path,
int8 beyond the near window; here the XLA decode path keeps bf16 storage
(the kernel variant is exercised in tests/benchmarks).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import attention, rmsnorm, rope
from .ssm import (mamba_forward, mamba_init_state, mlstm_forward,
                  mlstm_init_state, slstm_forward, slstm_init_state)
from .transformer import _apply_block, _sinusoid, encode

CACHE_DTYPE = jnp.bfloat16


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               kv_quant: bool = False):
    """Empty cache pytree for decode.

    kv_quant=True stores attention KV int8 with per-row fp32 scales --
    the XLA-path realization of the paper's distance-banded precision
    (its t=0 limit; the Pallas mp_attention kernel implements the true
    near-bf16/far-int8 band).  Halves the cache bytes that dominate the
    memory-bound decode cells."""
    c = cfg.n_cycles
    kv, hd = cfg.n_kv_heads, cfg.d_head
    cache = {}
    for i, bt in enumerate(cfg.block_pattern):
        key = f"b{i}"
        if bt == "attn":
            w = min(cfg.swa_window or max_len, max_len)
            dt = jnp.int8 if kv_quant else CACHE_DTYPE
            cache[key] = {
                "k": jnp.zeros((c, batch, w, kv, hd), dt),
                "v": jnp.zeros((c, batch, w, kv, hd), dt),
            }
            if kv_quant:
                cache[key]["k_scale"] = jnp.zeros((c, batch, w, kv),
                                                  jnp.float32)
                cache[key]["v_scale"] = jnp.zeros((c, batch, w, kv),
                                                  jnp.float32)
            if cfg.swa_window is not None:
                cache[key]["pos"] = jnp.full((c, w), -1, jnp.int32)
        elif bt == "mamba":
            st = mamba_init_state(batch, cfg)
            cache[key] = {"conv": jnp.broadcast_to(st[0], (c,) + st[0].shape),
                          "ssm": jnp.broadcast_to(st[1], (c,) + st[1].shape)}
        elif bt == "mlstm":
            st = mlstm_init_state(batch, cfg)
            cache[key] = {"c": jnp.broadcast_to(st[0], (c,) + st[0].shape),
                          "n": jnp.broadcast_to(st[1], (c,) + st[1].shape),
                          "m": jnp.broadcast_to(st[2], (c,) + st[2].shape)}
        elif bt == "slstm":
            st = slstm_init_state(batch, cfg)
            cache[key] = {k2: jnp.broadcast_to(v2, (c,) + v2.shape)
                          for k2, v2 in zip("cnhm", st)}
    if cfg.enc_dec:
        cache["cross"] = {
            "k": jnp.zeros((c, batch, cfg.n_enc_frames, kv, hd), CACHE_DTYPE),
            "v": jnp.zeros((c, batch, cfg.n_enc_frames, kv, hd), CACHE_DTYPE),
        }
    return cache


def _decode_attn(p, x, cfg: ArchConfig, cache, pos):
    """Single-token GQA attention against the cache. x: (B, 1, d)."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)

    w = cache["k"].shape[1]
    slot = pos % w if cfg.swa_window is not None else pos
    quant = "k_scale" in cache
    if quant:
        def _quantize_row(t):
            sc = jnp.max(jnp.abs(t), axis=-1) / 127.0 + 1e-12   # (B,1,KV)
            return jnp.round(t / sc[..., None]).astype(jnp.int8), sc
        k_q, k_sc = _quantize_row(k.astype(jnp.float32))
        v_q, v_sc = _quantize_row(v.astype(jnp.float32))
        ck = lax.dynamic_update_slice(cache["k"], k_q, (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v_q, (0, slot, 0, 0))
        ck_sc = lax.dynamic_update_slice(cache["k_scale"], k_sc, (0, slot, 0))
        cv_sc = lax.dynamic_update_slice(cache["v_scale"], v_sc, (0, slot, 0))
        new_cache = {"k": ck, "v": cv, "k_scale": ck_sc, "v_scale": cv_sc}
    else:
        ck = lax.dynamic_update_slice(cache["k"], k.astype(CACHE_DTYPE),
                                      (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(CACHE_DTYPE),
                                      (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
    if cfg.swa_window is not None:
        cpos = lax.dynamic_update_slice(cache["pos"],
                                        jnp.full((1,), pos, jnp.int32), (slot,))
        new_cache["pos"] = cpos
        valid = (cpos >= 0) & (cpos > pos - cfg.swa_window)
    else:
        valid = jnp.arange(w) <= pos

    qg = q.reshape(b, 1, kv, g, hd)
    if quant:
        ck_f = ck.astype(x.dtype) * ck_sc[..., None].astype(x.dtype)
        cv_f = cv.astype(x.dtype) * cv_sc[..., None].astype(x.dtype)
    else:
        ck_f, cv_f = ck.astype(x.dtype), cv.astype(x.dtype)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, ck_f,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    wts = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", wts, cv_f)
    out = out.reshape(b, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), new_cache


def _cross_from_cache(p, x, cfg: ArchConfig, cache):
    """Cross attention against the (fixed) encoder memory cache."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    qg = q.reshape(b, 1, kv, g, hd)
    ck, cv = cache["k"].astype(x.dtype), cache["v"].astype(x.dtype)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, ck,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    wts = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", wts, cv).reshape(b, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def _decode_block(p, x, cfg: ArchConfig, bt: str, cache, pos):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if bt == "attn":
        out, new_cache = _decode_attn(p["inner"], h, cfg, cache, pos)
    elif bt == "mamba":
        out, st = mamba_forward(p["inner"], h, cfg,
                                state=(cache["conv"], cache["ssm"]))
        new_cache = {"conv": st[0].astype(cache["conv"].dtype), "ssm": st[1]}
    elif bt == "mlstm":
        out, st = mlstm_forward(p["inner"], h, cfg,
                                state=(cache["c"], cache["n"], cache["m"]))
        new_cache = dict(zip("cnm", st))
    elif bt == "slstm":
        out, st = slstm_forward(p["inner"], h, cfg,
                                state=(cache["c"], cache["n"], cache["h"],
                                       cache["m"]))
        new_cache = dict(zip("cnhm", st))
    else:
        raise ValueError(bt)
    x = x + out
    if "cross" in p:
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        x = x + _cross_from_cache(p["cross"], hx, cfg, cache["__cross__"])
    if "ffn_moe" in p:
        hf = rmsnorm(p["norm2"], x, cfg.norm_eps)
        from .layers import moe
        out, _ = moe(p["ffn_moe"], hf, cfg.moe)
        x = x + out
    elif "ffn" in p:
        hf = rmsnorm(p["norm2"], x, cfg.norm_eps)
        from .layers import mlp
        x = x + mlp(p["ffn"], hf)
    return x, new_cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, *,
                compute_dtype=jnp.bfloat16):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 position.
    Returns (logits (B, 1, vocab) fp32, new cache)."""
    x = params["embed"][tokens].astype(compute_dtype)

    cross = cache.get("cross")

    def cycle_fn(x, scanned):
        cyc_params, cyc_cache, cyc_cross = scanned
        new_cache = {}
        for i, bt in enumerate(cfg.block_pattern):
            blk_cache = dict(cyc_cache[f"b{i}"])
            if cyc_cross is not None:
                blk_cache["__cross__"] = cyc_cross
            x_new, nc = _decode_block(cyc_params[f"b{i}"], x, cfg, bt,
                                      blk_cache, pos)
            x = x_new
            new_cache[f"b{i}"] = nc
        return x, new_cache

    block_cache = {k: v for k, v in cache.items() if k != "cross"}
    x, new_block_cache = lax.scan(
        cycle_fn, x, (params["cycles"], block_cache, cross))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(compute_dtype)
    logits = (x @ unembed).astype(jnp.float32)
    new_cache = dict(new_block_cache)
    if cross is not None:
        new_cache["cross"] = cross
    return logits, new_cache


# ------------------------------------------------------------- prefill

def prefill(params, tokens, cfg: ArchConfig, *, extra_embeds=None,
            frames=None, compute_dtype=jnp.bfloat16):
    """Process a full prompt, returning (logits, cache) ready for decode.

    The cache covers exactly the prompt length (padded to the SWA window
    for SWA archs); decode continues at pos = S.
    """
    b, s = tokens.shape
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(params, frames, cfg, compute_dtype=compute_dtype)
    x = params["embed"][tokens].astype(compute_dtype)
    if extra_embeds is not None:
        pe = extra_embeds.astype(compute_dtype)
        if "vision_adapter" in params:
            pe = pe @ params["vision_adapter"].astype(compute_dtype)
        x = jnp.concatenate([pe, x], axis=1)
    s_tot = x.shape[1]
    positions = jnp.arange(s_tot)[None, :].repeat(b, 0)

    kv, hd = cfg.n_kv_heads, cfg.d_head

    def cycle_fn(x, cyc):
        new_cache = {}
        for i, bt in enumerate(cfg.block_pattern):
            p = cyc[f"b{i}"]
            h = rmsnorm(p["norm1"], x, cfg.norm_eps)
            ffn_pending = False  # attn branch applies FFN below;
            # _apply_block already applies it for the other block types
            if bt == "attn":
                ffn_pending = True
                # run attention AND capture rope'd k/v for the cache
                k = jnp.einsum("bsd,dhk->bshk", h, p["inner"]["wk"].astype(h.dtype))
                v = jnp.einsum("bsd,dhk->bshk", h, p["inner"]["wv"].astype(h.dtype))
                if cfg.qk_norm:
                    k = rmsnorm(p["inner"]["k_norm"], k, cfg.norm_eps)
                kr = rope(k, positions, cfg.rope_theta)
                out = attention(p["inner"], h, cfg, positions=positions)
                x = x + out
                if cfg.swa_window is not None and cfg.swa_window < s_tot:
                    w = cfg.swa_window
                    new_cache[f"b{i}"] = {
                        "k": kr[:, -w:].astype(CACHE_DTYPE),
                        "v": v[:, -w:].astype(CACHE_DTYPE),
                        "pos": jnp.arange(s_tot - w, s_tot, dtype=jnp.int32),
                    }
                elif cfg.swa_window is not None:
                    pad = cfg.swa_window - s_tot
                    new_cache[f"b{i}"] = {
                        "k": jnp.pad(kr, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(CACHE_DTYPE),
                        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(CACHE_DTYPE),
                        "pos": jnp.concatenate([
                            jnp.arange(s_tot, dtype=jnp.int32),
                            jnp.full((pad,), -1, jnp.int32)]),
                    }
                else:
                    new_cache[f"b{i}"] = {"k": kr.astype(CACHE_DTYPE),
                                          "v": v.astype(CACHE_DTYPE)}
            else:
                x, _, st = _apply_block(p, x, cfg, bt, positions=positions,
                                        enc_out=enc_out)
                if bt == "mamba":
                    new_cache[f"b{i}"] = {"conv": st[0].astype(CACHE_DTYPE),
                                          "ssm": st[1]}
                elif bt == "mlstm":
                    new_cache[f"b{i}"] = dict(zip("cnm", st))
                elif bt == "slstm":
                    new_cache[f"b{i}"] = dict(zip("cnhm", st))
            if "cross" in p and enc_out is not None:
                hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
                out = attention(p["cross"], hx, cfg, positions=positions,
                                kv_x=enc_out, causal=False, use_rope=False)
                x = x + out
                ck = jnp.einsum("bsd,dhk->bshk", enc_out,
                                p["cross"]["wk"].astype(h.dtype))
                cv = jnp.einsum("bsd,dhk->bshk", enc_out,
                                p["cross"]["wv"].astype(h.dtype))
                if cfg.qk_norm:
                    ck = rmsnorm(p["cross"]["k_norm"], ck, cfg.norm_eps)
                new_cache["__cross__"] = {"k": ck.astype(CACHE_DTYPE),
                                          "v": cv.astype(CACHE_DTYPE)}
            if ffn_pending and "ffn_moe" in p:
                hf = rmsnorm(p["norm2"], x, cfg.norm_eps)
                from .layers import moe
                out, _ = moe(p["ffn_moe"], hf, cfg.moe)
                x = x + out
            elif ffn_pending and "ffn" in p:
                hf = rmsnorm(p["norm2"], x, cfg.norm_eps)
                from .layers import mlp
                x = x + mlp(p["ffn"], hf)
        return x, new_cache

    x, caches = lax.scan(cycle_fn, x, params["cycles"])
    # serving prefill: only the LAST position's logits are needed to start
    # decoding -- computing (B, S, V) logits at 32k cost 40 GiB/chip
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(compute_dtype)
    logits = (x @ unembed).astype(jnp.float32)
    cache = {k: v for k, v in caches.items() if k != "__cross__"}
    if "__cross__" in caches:
        cache["cross"] = caches["__cross__"]
    return logits, cache
