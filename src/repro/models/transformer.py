"""Full-model init + forward for every assigned architecture family.

Layers are grouped into cycles (config.block_pattern); per-cycle params are
stacked on a leading "cycles" axis (vmap over init keys) and the forward
pass lax.scans over them -- one compiled cycle body regardless of depth,
which keeps the 512-way SPMD dry-run compile tractable.

Families:
  dense/moe/vlm : decoder-only LM (vlm prepends stub patch embeddings)
  ssm           : xLSTM (alternating mLSTM/sLSTM cycles)
  hybrid        : jamba (attn + 7x mamba per cycle, MoE every other layer)
  audio         : whisper enc-dec (stub frame embeddings into the encoder)
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import (_init, attention, attention_init, mlp, mlp_init, moe,
                     moe_init, rmsnorm, rmsnorm_init)
from .sharding import ax, constrain
from .ssm import (mamba_forward, mamba_init, mlstm_forward, mlstm_init,
                  slstm_forward, slstm_init)

_INNER_INIT = {"attn": attention_init, "mamba": mamba_init,
               "mlstm": mlstm_init, "slstm": slstm_init}


def _block_init(key, cfg: ArchConfig, idx_in_pattern: int, *, cross=False):
    bt = cfg.block_pattern[idx_in_pattern % len(cfg.block_pattern)]
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["norm1"], a["norm1"] = rmsnorm_init(cfg.d_model)
    p["inner"], a["inner"] = _INNER_INIT[bt](ks[0], cfg)
    if cross:
        p["norm_x"], a["norm_x"] = rmsnorm_init(cfg.d_model)
        p["cross"], a["cross"] = attention_init(ks[1], cfg)
    has_ffn = bt in ("attn", "mamba") and (cfg.layer_is_moe(idx_in_pattern)
                                           or cfg.d_ff > 0)
    if has_ffn:
        p["norm2"], a["norm2"] = rmsnorm_init(cfg.d_model)
        if cfg.layer_is_moe(idx_in_pattern):
            p["ffn_moe"], a["ffn_moe"] = moe_init(ks[2], cfg)
        else:
            p["ffn"], a["ffn"] = mlp_init(ks[2], cfg)
    return p, a


def _apply_block(p, x, cfg: ArchConfig, bt: str, *, positions, state=None,
                 enc_out=None, causal=True):
    """One block: mixer + optional FFN, pre-norm residuals.
    Returns (x, aux_loss, new_state)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if bt == "attn":
        out = attention(p["inner"], h, cfg, positions=positions, causal=causal)
        new_state = None
    elif bt == "mamba":
        out, new_state = mamba_forward(p["inner"], h, cfg, state=state)
    elif bt == "mlstm":
        out, new_state = mlstm_forward(p["inner"], h, cfg, state=state)
    elif bt == "slstm":
        out, new_state = slstm_forward(p["inner"], h, cfg, state=state)
    else:
        raise ValueError(bt)
    x = x + out
    if "cross" in p:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        out = attention(p["cross"], h, cfg, positions=positions,
                        kv_x=enc_out, causal=False, use_rope=False)
        x = x + out
    if "ffn_moe" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        out, aux = moe(p["ffn_moe"], h, cfg.moe)
        x = x + out
    elif "ffn" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["ffn"], h)
    return x, aux, new_state


# --------------------------------------------------------------- init

def init_lm(key, cfg: ArchConfig):
    """Returns (params, logical_axes)."""
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["embed"] = _init(ks[0], (cfg.vocab, cfg.d_model), scale=0.02)
    a["embed"] = ax("vocab", "embed")
    if not cfg.tie_embeddings:
        p["unembed"] = _init(ks[1], (cfg.d_model, cfg.vocab))
        a["unembed"] = ax("embed", "vocab")
    p["final_norm"], a["final_norm"] = rmsnorm_init(cfg.d_model)

    def one_cycle(k):
        kk = jax.random.split(k, len(cfg.block_pattern))
        ps, as_ = {}, {}
        for i in range(len(cfg.block_pattern)):
            ps[f"b{i}"], as_[f"b{i}"] = _block_init(kk[i], cfg, i)
        return ps, as_

    cyc_keys = jax.random.split(ks[2], cfg.n_cycles)
    stacked = jax.vmap(lambda k: one_cycle(k)[0])(cyc_keys)
    _, cyc_axes = one_cycle(ks[2])
    p["cycles"] = stacked
    a["cycles"] = jax.tree.map(lambda s: "cycles " + s, cyc_axes)

    if cfg.enc_dec:
        # whisper: encoder cycles (bidirectional attn blocks) + decoder cross
        def enc_cycle(k):
            return _block_init(k, cfg, 0)  # "attn" pattern block

        assert cfg.block_pattern == ("attn",)
        enc_keys = jax.random.split(ks[3], cfg.n_enc_layers)
        p["enc_cycles"] = jax.vmap(lambda k: enc_cycle(k)[0])(enc_keys)
        _, ea = enc_cycle(ks[3])
        a["enc_cycles"] = jax.tree.map(lambda s: "cycles " + s, ea)
        p["enc_norm"], a["enc_norm"] = rmsnorm_init(cfg.d_model)
        # decoder cycles get a cross-attention sub-block: rebuild
        def dec_cycle(k):
            ps, as_ = {}, {}
            ps["b0"], as_["b0"] = _block_init(k, cfg, 0, cross=True)
            return ps, as_
        dec_keys = jax.random.split(ks[4], cfg.n_cycles)
        p["cycles"] = jax.vmap(lambda k: dec_cycle(k)[0])(dec_keys)
        _, da = dec_cycle(ks[4])
        a["cycles"] = jax.tree.map(lambda s: "cycles " + s, da)
    if cfg.frontend == "vision_stub":
        # anyres projector stub: patch embeddings arrive pre-projected; a
        # single linear adapter stands in for the vision tower output head
        p["vision_adapter"] = _init(ks[5], (cfg.d_model, cfg.d_model))
        a["vision_adapter"] = ax("embed", "embed_no_fsdp")
    return p, a


# ------------------------------------------------------------- forward

def _sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, frames, cfg: ArchConfig, *, compute_dtype=jnp.bfloat16):
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    b, f, _ = frames.shape
    x = frames.astype(compute_dtype)
    x = x + _sinusoid(jnp.arange(f), cfg.d_model).astype(compute_dtype)
    positions = jnp.arange(f)[None, :].repeat(b, 0)

    def cycle_fn(x, cyc):
        x, _, _ = _apply_block(cyc, x, cfg, "attn",
                               positions=positions, causal=False)
        return x, None

    fn = jax.checkpoint(cycle_fn) if cfg.remat else cycle_fn
    x, _ = lax.scan(fn, x, params["enc_cycles"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward_lm(params, tokens, cfg: ArchConfig, *, extra_embeds=None,
               enc_out=None, compute_dtype=jnp.bfloat16):
    """tokens: (B, S) int32 -> logits (B, S_total, vocab) fp32, aux loss.

    extra_embeds: (B, P, d) stub patch/frame embeddings prepended (vlm).
    enc_out: (B, F, d) encoder memory for enc-dec cross attention.
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(compute_dtype)
    if extra_embeds is not None:
        pe = extra_embeds.astype(compute_dtype)
        if "vision_adapter" in params:
            pe = pe @ params["vision_adapter"].astype(compute_dtype)
        x = jnp.concatenate([pe, x], axis=1)
    s_tot = x.shape[1]
    positions = jnp.arange(s_tot)[None, :].repeat(b, 0)

    def cycle_fn(carry, cyc):
        x, aux = carry
        x = constrain(x, ax("act_batch", ".", "."))
        for i, bt in enumerate(cfg.block_pattern):
            x, aux_i, _ = _apply_block(cyc[f"b{i}"], x, cfg, bt,
                                       positions=positions, enc_out=enc_out)
            aux = aux + aux_i
        x = constrain(x, ax("act_batch", ".", "."))
        return (x, aux), None

    carry0 = (x, jnp.zeros((), jnp.float32))
    group = cfg.remat_group if cfg.remat else 1
    if group > 1 and cfg.n_cycles % group == 0:
        # 2-level NESTED remat: the outer scan saves n_cycles/group
        # carries; each inner cycle is checkpointed too, so the inner
        # backward holds one cycle's intermediates at a time (without the
        # inner checkpoint the rematted recompute stacks `group` cycles of
        # full intermediates -- measured +23 GiB on llama3.2-1b).
        outer = cfg.n_cycles // group
        re_params = jax.tree.map(
            lambda a: a.reshape((outer, group) + a.shape[1:]),
            params["cycles"])
        inner_fn = jax.checkpoint(cycle_fn) if cfg.remat else cycle_fn

        def outer_fn(carry, chunk):
            carry, _ = lax.scan(inner_fn, carry, chunk)
            return carry, None

        fn = jax.checkpoint(outer_fn) if cfg.remat else outer_fn
        (x, aux), _ = lax.scan(fn, carry0, re_params)
    else:
        fn = jax.checkpoint(cycle_fn) if cfg.remat else cycle_fn
        (x, aux), _ = lax.scan(fn, carry0, params["cycles"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(compute_dtype)
    logits = (x @ unembed).astype(jnp.float32)
    logits = constrain(logits, ax("act_batch", ".", "act_vocab"))
    return logits, aux


def lm_loss(params, batch, cfg: ArchConfig, *, compute_dtype=jnp.bfloat16):
    """Next-token CE + MoE aux.  batch: {tokens, labels, [patches|frames]}."""
    enc_out = None
    extra = None
    if cfg.enc_dec:
        enc_out = encode(params, batch["frames"], cfg,
                         compute_dtype=compute_dtype)
    if cfg.frontend == "vision_stub":
        extra = batch["patches"]
    logits, aux = forward_lm(params, batch["tokens"], cfg, extra_embeds=extra,
                             enc_out=enc_out, compute_dtype=compute_dtype)
    labels = batch["labels"]
    if extra is not None:
        logits = logits[:, -labels.shape[1]:]  # loss only on the text part
    # Vocab-sharding-safe CE: log_softmax reduces over the (model-sharded)
    # vocab dim and the label pick is a one-hot contraction -- GSPMD lowers
    # both to cheap (B, S) all-reduces instead of all-gathering the
    # (B, S, V) logits (which peaked at 141 GiB/chip; EXPERIMENTS.md §Perf).
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logp.dtype)
    nll = -jnp.einsum("bsv,bsv->bs", logp, onehot)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux, {"ce": loss, "aux": aux}
