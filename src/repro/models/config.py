"""Architecture configuration for the assigned model zoo.

One frozen dataclass covers all 10 assigned families (dense / MoE / SSM /
hybrid / enc-dec / VLM-stub / audio-stub).  Layers are grouped into
*cycles*: `block_pattern` is the sequence of block types inside one cycle
(e.g. jamba's ("attn", "mamba" x7)), and parameters for the repeated cycle
are stacked on a leading axis so the forward pass can lax.scan over cycles
(small HLO, fast SPMD compile -- essential for the 512-chip dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int             # per-expert FFN hidden size
    every: int = 1            # MoE on layers where (layer_idx % every == rem)
    rem: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                         # dense-MLP hidden (0 = no MLP block)
    vocab: int
    d_head: int = 0                   # 0 -> d_model // n_heads
    block_pattern: tuple = ("attn",)  # block types inside one cycle
    moe: Optional[MoESpec] = None
    qk_norm: bool = False
    swa_window: Optional[int] = None  # sliding-window attention
    rope_theta: float = 1e6
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_enc_frames: int = 1500          # whisper encoder memory length
    frontend: Optional[str] = None    # "audio_stub" | "vision_stub"
    n_patches: int = 0                # vlm: image patch-embedding count
    # ssm/mamba/xlstm
    ssm_d_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_chunk: int = 128
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # training
    remat: bool = True
    remat_group: int = 1   # cycles per outer scan step (2-level remat):
                           # saved carries drop from n_cycles to
                           # n_cycles/remat_group at the cost of one extra
                           # inner forward during backward

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of the "
            f"block pattern length {len(self.block_pattern)}")

    @property
    def n_cycles(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def attention_is_subquadratic(self) -> bool:
        """True if long-context decode is feasible (SSM/hybrid/SWA)."""
        kinds = set(self.block_pattern)
        if kinds <= {"mamba", "mlstm", "slstm"}:
            return True
        if "attn" in kinds and self.swa_window is not None:
            return True
        # hybrid: a few attn layers with seq-sharded KV is acceptable
        if "mamba" in kinds and "attn" in kinds:
            return True
        return False

    def layer_block_type(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def layer_is_moe(self, layer_idx: int) -> bool:
        return (self.moe is not None
                and layer_idx % self.moe.every == self.moe.rem)

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced copy for CPU smoke tests."""
        return dataclasses.replace(self, **overrides)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, hd = self.d_model, self.d_head
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            bt = self.layer_block_type(i)
            if bt == "attn":
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d
            elif bt == "mamba":
                d_in = self.ssm_expand * d
                total += d * 2 * d_in + d_in * self.ssm_conv
                total += d_in * (2 * self.ssm_d_state + 1) + d_in * self.ssm_d_state
                total += d_in * d
            elif bt == "mlstm":
                d_in = self.ssm_expand * d
                total += d * 2 * d_in + 3 * d_in * hd * 0  # gates folded below
                total += 4 * d_in * d_in // max(self.n_heads, 1) * 0
                total += 3 * d_in * d_in + 3 * d_in + d_in * d
            elif bt == "slstm":
                total += 4 * d * d + 4 * d * d // max(self.n_heads, 1)
                total += (4 * d // 3) * d * 2
            if self.layer_is_moe(i):
                total += d * self.moe.n_experts  # router
                total += self.moe.n_experts * 3 * d * self.moe.d_expert
            elif self.d_ff and bt in ("attn", "mamba"):
                total += 3 * d * self.d_ff
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += 4 * d * hd * self.n_heads + 3 * d * self.d_ff
                total += 4 * d * hd * self.n_heads  # cross attention
        return total


ARCH_REGISTRY: dict = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in ARCH_REGISTRY:
        # populate the registry lazily
        from ..configs import ALL_ARCHS  # noqa: F401
    return ARCH_REGISTRY[name]
