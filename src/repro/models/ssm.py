"""Recurrent blocks: Mamba (jamba hybrid) and xLSTM (mLSTM/sLSTM).

Each block type ships three functions: init, a sequence-parallel train/
prefill form, and a single-token decode step with explicit state (the
"KV cache" analogue for SSMs -- constant-size, which is why these archs
keep the `long_500k` cell that dense attention skips).

Mamba uses a chunked selective scan (associative scan inside a chunk,
lax.scan across chunks) so peak memory is O(B * chunk * d_in * N) instead
of O(B * S * d_in * N).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import _init, rmsnorm, rmsnorm_init
from .sharding import ax


# ------------------------------------------------------------------ mamba

def mamba_init(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    r = max(1, d // 16)  # dt rank
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": _init(ks[0], (d, 2 * d_in)),
        "conv_w": _init(ks[1], (d_in, cfg.ssm_conv), scale=0.5),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": _init(ks[2], (d_in, r + 2 * n)),
        "dt_proj": _init(ks[3], (r, d_in), scale=1.0 / math.sqrt(r)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, jnp.float32))),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (d_in, 1))),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": _init(ks[4], (d_in, d), scale=1.0 / math.sqrt(d_in)),
    }
    a = {
        "in_proj": ax("embed", "ssm_inner"),
        "conv_w": ax("ssm_inner", "conv"),
        "conv_b": ax("ssm_inner"),
        "x_proj": ax("ssm_inner", "."),
        "dt_proj": ax(".", "ssm_inner"),
        "dt_bias": ax("ssm_inner"),
        "a_log": ax("ssm_inner", "ssm_state"),
        "d_skip": ax("ssm_inner"),
        "out_proj": ax("ssm_inner", "embed"),
    }
    return p, a


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv along seq via shifted adds.

    x: (B, S, d_in); w: (d_in, K).  conv_state: (B, K-1, d_in) history for
    decode continuity (returns updated state)."""
    k = w.shape[1]
    if conv_state is None:
        hist = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        hist = conv_state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)          # (B, S+K-1, d_in)
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(k):
        out = out + xp[:, i:i + s, :] * w[:, i].astype(x.dtype)
    new_state = xp[:, -(k - 1):, :] if k > 1 else hist
    return out + b.astype(x.dtype), new_state


def _ssm_scan_chunked(dt, a, b_mat, c_mat, x_c, h0, chunk: int):
    """Fused chunked selective scan: y_t = C_t . h_t with
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    The (B, S, d_in, N) decay/input tensors are NEVER materialized at full
    sequence length -- each checkpointed chunk step builds its own
    (B, chunk, d_in, N) slice, runs an associative scan, and contracts to
    y immediately.  Without this, one jamba mamba layer transiently held
    2 x 17 GiB/chip at train_4k; with it, ~1 GiB (EXPERIMENTS.md §Perf).

    dt, x_c: (B, S, d_in); b_mat, c_mat: (B, S, N); a: (d_in, N) fp32.
    Returns (y (B, S, d_in) fp32, h_last (B, d_in, N))."""
    b, s, d_in = dt.shape
    n = a.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zpad = lambda x_: jnp.concatenate(
            [x_, jnp.zeros((b, pad) + x_.shape[2:], x_.dtype)], axis=1)
        dt, x_c = zpad(dt), zpad(x_c)                # dt=0 -> decay=1, inp=0
        b_mat, c_mat = zpad(b_mat), zpad(c_mat)
    s_pad = s + pad
    nchunks = s_pad // chunk

    def to_chunks(x_):
        return x_.reshape((b, nchunks, chunk) + x_.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, x_.ndim + 1)))

    xs = (to_chunks(dt.astype(jnp.float32)), to_chunks(b_mat.astype(jnp.float32)),
          to_chunks(c_mat.astype(jnp.float32)), to_chunks(x_c.astype(jnp.float32)))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, chunk_xs):
        dt_c, b_c, c_c, x_cc = chunk_xs              # (B, chunk, ...)
        decay = jnp.exp(dt_c[..., None] * a)         # (B, chunk, d_in, N)
        inp = dt_c[..., None] * b_c[:, :, None, :] * x_cc[..., None]
        a_cum, b_cum = lax.associative_scan(combine, (decay, inp), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c_c)
        return h_all[:, -1], y

    step = jax.checkpoint(step)
    h_last, y_chunks = lax.scan(step, h0, xs)
    y = y_chunks.transpose(1, 0, 2, 3).reshape(b, s_pad, d_in)
    return y[:, :s], h_last


def mamba_forward(p, x, cfg: ArchConfig, *, state=None):
    """x: (B, S, d). state: None or (conv_state, ssm_state) for continuity.
    Returns (y, new_state)."""
    b, s, d = x.shape
    n = cfg.ssm_d_state
    d_in = cfg.ssm_expand * d
    conv_state = state[0] if state is not None else None
    h0 = (state[1] if state is not None
          else jnp.zeros((b, d_in, n), jnp.float32))

    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)

    dbc = x_c @ p["x_proj"].astype(x.dtype)
    r = p["dt_proj"].shape[0]
    dt_r, b_mat, c_mat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))      # (B,S,d_in)
    a = -jnp.exp(p["a_log"])                                  # (d_in, N)

    y, h_last = _ssm_scan_chunked(dt, a, b_mat, c_mat, x_c, h0,
                                  cfg.mamba_chunk)
    y = y.astype(x.dtype)
    y = y + p["d_skip"].astype(x.dtype) * x_c
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (new_conv, h_last)


def mamba_decode_step(p, x, cfg: ArchConfig, state):
    """x: (B, 1, d) -> (y (B,1,d), new_state)."""
    return mamba_forward(p, x, cfg, state=state)


def mamba_init_state(b, cfg: ArchConfig, dtype=jnp.bfloat16):
    d_in = cfg.ssm_expand * cfg.d_model
    return (jnp.zeros((b, cfg.ssm_conv - 1, d_in), dtype),
            jnp.zeros((b, d_in, cfg.ssm_d_state), jnp.float32))


def _checkpointed_seq_scan(step, carry, xs, chunk: int):
    """lax.scan over time with per-chunk jax.checkpoint.

    Sequential recurrences (mLSTM matrix memory, sLSTM) save their carry
    at EVERY step under plain autodiff -- 275 TB for xlstm-1.3b at
    train_4k.  Chunked checkpointing stores only chunk-boundary states and
    recomputes inside a chunk (S/chunk boundaries + chunk-transient).
    xs: pytree, leading dim = time.  Falls back to one unchunked scan when
    the length is not a chunk multiple (CPU smoke shapes)."""
    s = jax.tree.leaves(xs)[0].shape[0]
    if chunk >= s or s % chunk != 0:
        return lax.scan(step, carry, xs)
    nchunks = s // chunk
    xs_c = jax.tree.map(
        lambda a_: a_.reshape((nchunks, chunk) + a_.shape[1:]), xs)

    @jax.checkpoint
    def chunk_fn(c, cxs):
        return lax.scan(step, c, cxs)

    carry, ys_c = lax.scan(chunk_fn, carry, xs_c)
    ys = jax.tree.map(
        lambda a_: a_.reshape((s,) + a_.shape[2:]), ys_c)
    return carry, ys


_MLSTM_CHUNK = 64
_SLSTM_CHUNK = 256


# ------------------------------------------------------------------ mlstm

def mlstm_init(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    p = {
        "up_proj": _init(ks[0], (d, 2 * d_in)),
        "wq": _init(ks[1], (d_in, d_in)),
        "wk": _init(ks[2], (d_in, d_in)),
        "wv": _init(ks[3], (d_in, d_in)),
        "w_igate": _init(ks[4], (d_in, h), scale=0.01),
        "w_fgate": _init(ks[5], (d_in, h), scale=0.01),
        "b_igate": jnp.zeros((h,), jnp.float32),
        "b_fgate": jnp.full((h,), 3.0, jnp.float32),  # forget-bias init
        "out_norm": jnp.ones((d_in,), jnp.float32),
        "down_proj": _init(ks[6], (d_in, d), scale=1.0 / math.sqrt(d_in)),
    }
    a = {
        "up_proj": ax("embed", "ssm_inner"),
        "wq": ax("ssm_inner", "."), "wk": ax("ssm_inner", "."),
        "wv": ax("ssm_inner", "."),
        "w_igate": ax("ssm_inner", "heads"), "w_fgate": ax("ssm_inner", "heads"),
        "b_igate": ax("heads"), "b_fgate": ax("heads"),
        "out_norm": ax("ssm_inner"),
        "down_proj": ax("ssm_inner", "embed"),
    }
    return p, a


def _mlstm_scan(q, k, v, ig, fg, state):
    """Stabilized exponential-gating matrix-memory recurrence.

    q,k,v: (B, S, H, hd); ig,fg: (B, S, H) log-space gates.
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)).  Sequential lax.scan --
    one HLO while loop, compile-cheap; see DESIGN.md for the chunked
    alternative considered in the perf log."""
    def step(carry, xs):
        c_mat, n_vec, m = carry
        qt, kt, vt, igt, fgt = xs                     # (B,H,hd)x3, (B,H)x2
        m_new = jnp.maximum(fgt + m, igt)
        fprime = jnp.exp(fgt + m - m_new)[..., None]
        iprime = jnp.exp(igt - m_new)[..., None]
        c_new = (c_mat * fprime[..., None]
                 + iprime[..., None] * kt[..., :, None] * vt[..., None, :])
        n_new = n_vec * fprime + iprime * kt
        denom = jnp.maximum(
            jnp.abs(jnp.sum(n_new * qt, axis=-1, keepdims=True)), 1.0)
        h = jnp.einsum("bhij,bhi->bhj", c_new, qt) / denom
        return (c_new, n_new, m_new), h

    qs = jnp.moveaxis(q, 1, 0)
    ks_ = jnp.moveaxis(k, 1, 0)
    vs = jnp.moveaxis(v, 1, 0)
    igs = jnp.moveaxis(ig, 1, 0)
    fgs = jnp.moveaxis(fg, 1, 0)
    state, hs = _checkpointed_seq_scan(step, state, (qs, ks_, vs, igs, fgs),
                                       _MLSTM_CHUNK)
    return jnp.moveaxis(hs, 0, 1), state              # (B,S,H,hd)


def mlstm_forward(p, x, cfg: ArchConfig, *, state=None):
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    h = cfg.n_heads
    hd = d_in // h
    if state is None:
        state = mlstm_init_state(b, cfg)

    xz = x @ p["up_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    q = (x_in @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x_in @ p["wk"].astype(x.dtype)).reshape(b, s, h, hd) / math.sqrt(hd)
    v = (x_in @ p["wv"].astype(x.dtype)).reshape(b, s, h, hd)
    ig = (x_in @ p["w_igate"].astype(x.dtype)).astype(jnp.float32) + p["b_igate"]
    fg = jax.nn.log_sigmoid(
        (x_in @ p["w_fgate"].astype(x.dtype)).astype(jnp.float32) + p["b_fgate"])

    hs, state = _mlstm_scan(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), ig, fg, state)
    hs = hs.astype(x.dtype).reshape(b, s, d_in)
    hs = rmsnorm({"scale": p["out_norm"]}, hs, cfg.norm_eps)
    out = (hs * jax.nn.silu(z)) @ p["down_proj"].astype(x.dtype)
    return out, state


def mlstm_init_state(b, cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd = d_in // h
    return (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))


# ------------------------------------------------------------------ slstm

def slstm_init(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    p = {
        "w_in": _init(ks[0], (d, 4 * d)),             # z, i, f, o pre-acts
        "r": _init(ks[1], (h, hd, 4 * hd), scale=1.0 / math.sqrt(hd)),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.full((d,), 3.0), jnp.zeros((d,))]).astype(jnp.float32),
        "out_proj": _init(ks[2], (d, d)),
    }
    a = {
        "w_in": ax("embed", "."),
        "r": ax("heads", "head_dim", "."),
        "b": ax("."),
        "out_proj": ax("embed", "embed_no_fsdp"),
    }
    return p, a


def slstm_forward(p, x, cfg: ArchConfig, *, state=None):
    """Scalar-memory LSTM with exponential gating + block-diagonal
    recurrence (one head = one block).  Sequential over S by definition
    (the recurrence is non-associative)."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    if state is None:
        state = slstm_init_state(b, cfg)

    pre = (x @ p["w_in"].astype(x.dtype)).astype(jnp.float32) + p["b"]

    def step(carry, pre_t):
        c, n, hprev, m = carry                       # (B, H, hd) x3, (B,H,hd)
        rec = jnp.einsum("bhi,hij->bhj", hprev, p["r"])   # (B, H, 4*hd)
        # pre_t: (B, 4d) laid out [z | i | f | o]; regroup per head
        pre_h = pre_t.reshape(b, 4, h, hd).transpose(0, 2, 1, 3).reshape(
            b, h, 4 * hd)
        zi, ii, fi, oi = jnp.split(pre_h, 4, axis=-1)
        zr, ir, fr, orr = jnp.split(rec, 4, axis=-1)
        zt = jnp.tanh(zi + zr)
        it = ii + ir
        ft = fi + fr
        ot = jax.nn.sigmoid(oi + orr)
        m_new = jnp.maximum(ft + m, it)
        iprime = jnp.exp(it - m_new)
        fprime = jnp.exp(ft + m - m_new)
        c_new = fprime * c + iprime * zt
        n_new = fprime * n + iprime
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    pres = jnp.moveaxis(pre, 1, 0)                    # (S, B, 4d)
    state, hs = _checkpointed_seq_scan(step, state, pres, _SLSTM_CHUNK)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    return hs @ p["out_proj"].astype(x.dtype), state


def slstm_init_state(b, cfg: ArchConfig):
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = lambda: jnp.zeros((b, h, hd), jnp.float32)
    return (z(), z(), z(), jnp.full((b, h, hd), -1e30, jnp.float32))
