"""Logical-axis sharding: model code declares WHAT each dim is, the mesh
layer decides WHERE it goes (MaxText-style logical axis rules).

Every parameter initializer returns (array, logical_axes) where
logical_axes is a tuple of strings, one per dim.  `resolve_spec` maps
logical names -> physical mesh axes with divisibility checking, so the
same model code runs on the 1-device CPU smoke mesh, the 16x16 pod and
the 2x16x16 multi-pod mesh without edits.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred physical axes, in priority order.
# "fsdp" rules shard parameters over the data axis (ZeRO-3 style); XLA
# all-gathers them per scan step, which is what keeps grok-1-314b's fp32
# master + Adam state inside the 16 GB/chip HBM budget (DESIGN.md §10).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                    # activations: unsharded by default
    "seq_shard": ("data",),       # long-context KV/state sharding (SP)
    "embed": ("data",),           # fsdp dim of params
    "embed_no_fsdp": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ffn": ("model",),
    "experts": ("model",),        # EP
    "expert_ffn": ("model",),     # fallback TP when n_experts < model axis
                                  # (grok-1: 8 experts on a 16-way axis)
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv": (),
    "cycles": (),                 # stacked scan layers: never sharded
    "frames": (),
    # activation constraints (see constrain() below)
    "act_batch": ("pod", "data"),
    "act_vocab": ("model",),
    "act_ffn": ("model",),
    "act_heads": ("model",),
    "act_experts": ("model",),
    "act_expert_cap": ("data",),  # MoE dispatch-capacity dim
    "act_expert_flat": ("model", "data"),  # flattened (E*C) dispatch dim
    "act_tokens": ("pod", "data"),         # flattened (B*S) token dim
    "act_moe_groups": ("pod", "data"),     # GShard routing-group dim
    # geostat distributed Cholesky (core/distributed.py)
    "geo_rows": ("data",),
    "geo_cols": ("model",),
    # fori variant: traced-offset column slices forbid column sharding
    # inside the loop carry, so rows take BOTH axes (1-D x 256-way)
    "geo_rows2d": ("data", "model"),
    None: (),
}

# ---------------------------------------------------------------------
# Activation sharding constraints.
#
# GSPMD propagates parameter shardings into activations, but with FSDP
# ("embed" over data) the propagation pass can resolve the conflict the
# wrong way: replicate the *batch* over data and keep weights sharded --
# observed as 141 GiB/chip activation buffers on llama3.2-1b:train_4k
# (EXPERIMENTS.md §Perf iteration 1).  constrain() pins the batch/ffn/
# vocab dims of key activations.  It is a no-op unless the launcher has
# installed a mesh (set_activation_mesh), so model code stays mesh-free
# and smoke tests on 1 device are unaffected.
# ---------------------------------------------------------------------

_ACTIVATION_MESH: list = [None]


def set_activation_mesh(mesh):
    """Install (or clear, with None) the mesh used by constrain()."""
    _ACTIVATION_MESH[0] = mesh


def constrain(x, logical_axes: str, *, allow_uneven: bool = False):
    mesh = _ACTIVATION_MESH[0]
    if mesh is None:
        return x
    spec = resolve_spec(logical_axes, mesh, shape=x.shape,
                        allow_uneven=allow_uneven)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def ax(*names: str) -> str:
    """Pack logical dim names into a single pytree-leaf string.

    A tuple would itself be a pytree (breaking tree.map against the params
    tree), so logical axes travel as space-joined strings: ax("embed",
    "heads", "head_dim") -> "embed heads head_dim".  "." means unsharded.
    """
    return " ".join(n if n is not None else "." for n in names)


def resolve_spec(logical_axes: str, mesh: Mesh, rules=None,
                 shape=None, allow_uneven: bool = False) -> P:
    """Map packed logical axis names to a PartitionSpec on `mesh`.

    Divisibility fallback: a physical axis is only used if the dim size is
    divisible by the axis size (checked when `shape` is provided).
    allow_uneven (activation constraints only): accept non-divisible dims
    when dim >= axis size -- GSPMD pads (llava's 56 heads on a 16-way
    axis cost <13% padding vs 16x replication).
    """
    rules = rules or DEFAULT_RULES
    names = logical_axes.split(" ") if logical_axes else []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    spec = []
    for i, name in enumerate(names):
        cands = rules.get(name, ()) if name != "." else ()
        placed = ()
        for axname in cands:
            if axname not in axis_sizes or axname in used:
                continue
            if shape is not None and shape[i] % axis_sizes[axname] != 0:
                if not (allow_uneven and shape[i] >= axis_sizes[axname]):
                    continue
            placed = placed + (axname,)
            used.add(axname)
        if len(placed) == 0:
            spec.append(None)
        elif len(placed) == 1:
            spec.append(placed[0])
        else:
            spec.append(placed)
    return P(*spec)


def tree_resolve_shardings(params, logical_tree, mesh: Mesh, rules=None):
    """params pytree + parallel logical-axes pytree -> NamedSharding tree."""
    def one(arr, axes):
        spec = resolve_spec(axes, mesh, rules, shape=arr.shape)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, params, logical_tree)


def batch_spec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    """Input batch sharding: batch over (pod, data); optionally the seq dim
    over data (long-context cells where batch < n_data)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if seq_sharded:
        return P(None, tuple(a for a in ("data",) if a in mesh.axis_names))
    return P(tuple(axes))
