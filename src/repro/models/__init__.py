from .config import ARCH_REGISTRY, ArchConfig, MoESpec, get_arch, register_arch
from .decode import decode_step, init_cache, prefill
from .sharding import DEFAULT_RULES, ax, batch_spec, resolve_spec, tree_resolve_shardings
from .transformer import encode, forward_lm, init_lm, lm_loss
