"""Neural blocks for the model zoo: init + apply, pure functions.

Every init function returns (params, logical_axes) pytrees with identical
structure; logical axes are packed strings (see sharding.ax).  Apply
functions are jit/scan/vmap-friendly and take activations in
`compute_dtype` (bf16 for the TPU path) with fp32 params.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig, MoESpec
from .sharding import ax, constrain


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------- rmsnorm

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ax(".")}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * p["scale"]).astype(dt)


# ------------------------------------------------------------------ rope

def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention

def attention_init(key, cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h, hd)),
        "wk": _init(ks[1], (d, kv, hd)),
        "wv": _init(ks[2], (d, kv, hd)),
        "wo": _init(ks[3], (h, hd, d), scale=1.0 / math.sqrt(h * hd)),
    }
    a = {
        "wq": ax("embed", "heads", "head_dim"),
        "wk": ax("embed", "kv_heads", "head_dim"),
        "wv": ax("embed", "kv_heads", "head_dim"),
        "wo": ax("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = rmsnorm_init(hd)
        p["k_norm"], a["k_norm"] = rmsnorm_init(hd)
    return p, a


def _attn_mask(sq, skv, *, causal: bool, swa: int | None, q_offset=0):
    """(sq, skv) boolean mask. q_offset = absolute position of query 0."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kpos <= qpos
    if swa is not None:
        m &= kpos > qpos - swa
    return m


_QCHUNK_THRESHOLD = 8192  # above this, query-chunk the S x S score matrix
_QCHUNK = 2048


def attention(p, x, cfg: ArchConfig, *, positions, kv_x=None, causal=True,
              use_rope=True, mask=None):
    """GQA attention. x: (B, S, d). kv_x for cross-attention.

    Long sequences (32k prefill) are processed in query chunks so the
    score buffer is (B, H, qchunk, S) instead of (B, H, S, S) -- the jnp
    flash-attention analogue that keeps the 32k cells inside VMEM/HBM
    budgets (EXPERIMENTS.md §Perf)."""
    b, sq, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if kv_x is None else
                 jnp.arange(src.shape[1])[None, :].repeat(b, 0), cfg.rope_theta)
    qg = q.reshape(b, sq, kv, g, hd)
    skv = src.shape[1]
    swa = cfg.swa_window if kv_x is None else None

    def block(q_blk, q_offset, blk_mask):
        scores = jnp.einsum("bskgh,btkh->bkgst", q_blk, k,
                            preferred_element_type=jnp.float32)
        # Pin batch AND give the merged (kv,g) head dim a model-axis home:
        # PartitionSpec can't split one mesh axis across the separate
        # kv/g dims, and an unpinned score tensor lets GSPMD replicate
        # batch when heads don't divide the axis (whisper: 48 GiB chunks).
        # allow_uneven handles llava's 56 heads on 16 (pad, not replicate).
        bq, sq_b = scores.shape[0], scores.shape[3]
        skv_b = scores.shape[4]
        merged = scores.reshape(bq, kv * g, sq_b, skv_b)
        merged = constrain(merged, ax("act_batch", "act_heads", ".", "."),
                           allow_uneven=True)
        scores = merged.reshape(bq, kv, g, sq_b, skv_b)
        scores = scores / math.sqrt(hd)
        if blk_mask is not None:
            scores = jnp.where(blk_mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return jnp.einsum("bkgst,btkh->bskgh", w, v)

    if sq >= _QCHUNK_THRESHOLD and mask is None and sq % _QCHUNK == 0:
        nblk = sq // _QCHUNK
        qg_b = qg.reshape(b, nblk, _QCHUNK, kv, g, hd)

        def scan_fn(_, i):
            q_blk = jax.lax.dynamic_index_in_dim(qg_b, i, axis=1,
                                                 keepdims=False)
            m = (_attn_mask(_QCHUNK, skv, causal=causal, swa=swa,
                            q_offset=i * _QCHUNK)
                 if (causal or swa) else None)
            return None, block(q_blk, i * _QCHUNK, m)

        _, outs = jax.lax.scan(scan_fn, None, jnp.arange(nblk))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)
    else:
        if mask is None and (causal or swa):
            mask = _attn_mask(sq, skv, causal=causal, swa=swa)
        out = block(qg, 0, mask).reshape(b, sq, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ----------------------------------------------------------- swiglu mlp

def mlp_init(key, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_gate": _init(ks[0], (d, f)), "w_up": _init(ks[1], (d, f)),
         "w_down": _init(ks[2], (f, d))}
    a = {"w_gate": ax("embed", "ffn"), "w_up": ax("embed", "ffn"),
         "w_down": ax("ffn", "embed")}
    return p, a


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    h = constrain(h, ax("act_batch", ".", "act_ffn"))
    return h @ p["w_down"].astype(x.dtype)


# ------------------------------------------------------------------ moe

def moe_init(key, cfg: ArchConfig):
    d, spec = cfg.d_model, cfg.moe
    e, fe = spec.n_experts, spec.d_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": _init(ks[0], (d, e)),
        "w_gate": _init(ks[1], (e, d, fe)),
        "w_up": _init(ks[2], (e, d, fe)),
        "w_down": _init(ks[3], (e, fe, d), scale=1.0 / math.sqrt(fe)),
    }
    a = {
        "router": ax("embed", "experts"),
        "w_gate": ax("experts", "embed", "expert_ffn"),
        "w_up": ax("experts", "embed", "expert_ffn"),
        "w_down": ax("experts", "expert_ffn", "embed"),
    }
    return p, a


_MOE_GROUPS = 32  # dispatch groups (GShard-style); shards over (pod, data)


def _moe_group_count(t: int, e: int) -> int:
    """Largest group count <= _MOE_GROUPS keeping >= 4*E tokens per group
    (decode batches route globally; training shards into 32 groups)."""
    g = _MOE_GROUPS
    while g > 1 and (t // g) < 4 * e:
        g //= 2
    while t % g:
        g //= 2
    return max(g, 1)


def moe(p, x, spec: MoESpec):
    """Top-k token-choice MoE: GROUPED sort-based capacity dispatch.

    GSPMD cannot partition a scatter/gather with arbitrary indices along
    the scattered dim -- it all-gathers the operand (8 GiB/chip flat token
    buffers on jamba, EXPERIMENTS.md D10).  GShard's fix, used here:
    tokens split into G routing groups with per-group capacity; dispatch
    gather/scatter become *batched* ops over the group dim, which GSPMD
    partitions cleanly (groups -> data axis, experts -> model axis; the
    expert einsum produces the EP all-to-alls).  Returns (y, aux_loss).
    """
    b, s, d = x.shape
    t = b * s
    e, k = spec.n_experts, spec.top_k
    g_cnt = _moe_group_count(t, e)
    tg = t // g_cnt                              # tokens per group
    c = max(4, int(spec.capacity_factor * tg * k / e))
    xf = x.reshape(g_cnt, tg, d)
    xf = constrain(xf, ax("act_moe_groups", ".", "."))

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, Tg, E)
    gate_vals, gate_idx = lax.top_k(probs, k)                # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_e = gate_idx.reshape(g_cnt, tg * k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (g_cnt, tg * k))
    flat_g = gate_vals.reshape(g_cnt, tg * k)

    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, -1)
    st = jnp.take_along_axis(flat_t, order, -1)
    sg = jnp.take_along_axis(flat_g, order, -1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, -1) - counts                 # (G, E)
    pos_in_e = jnp.arange(tg * k)[None] - jnp.take_along_axis(starts, se, -1)
    keep = pos_in_e < c
    slot = jnp.where(keep, se * c + pos_in_e, e * c)         # e*c = trash

    table = jnp.full((g_cnt, e * c + 1), tg, jnp.int32)
    table = table.at[jnp.arange(g_cnt)[:, None], slot].set(st, mode="drop")
    gates = jnp.zeros((g_cnt, e * c + 1), jnp.float32)
    gates = gates.at[jnp.arange(g_cnt)[:, None], slot].set(sg, mode="drop")
    table, gates = table[:, :-1], gates[:, :-1]

    # batched OOB-fill gather: (G, E*C, d), partitionable along G
    xg = jax.vmap(lambda xrow, trow: xrow.at[trow].get(mode="fill",
                                                       fill_value=0))(xf, table)
    xg = constrain(xg, ax("act_moe_groups", ".", "."))
    xe = xg.reshape(g_cnt, e, c, d)
    xe = constrain(xe, ax("act_moe_groups", "act_experts", ".", "."))
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                p["w_gate"].astype(x.dtype)))
         * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype)))
    h = constrain(h, ax("act_moe_groups", "act_experts", ".", "act_ffn"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    ye = constrain(ye, ax("act_moe_groups", "act_experts", ".", "."))
    ye_flat = ye.reshape(g_cnt, e * c, d)

    # batched OOB-drop combine scatter
    yflat = jnp.zeros((g_cnt, tg, d), jnp.float32)
    yflat = yflat.at[jnp.arange(g_cnt)[:, None], table].add(
        ye_flat.astype(jnp.float32) * gates[..., None], mode="drop")
    yflat = constrain(yflat, ax("act_moe_groups", ".", "."))
    y = yflat.reshape(b, s, d).astype(x.dtype)

    # switch-style load-balance aux loss (global across groups)
    frac_tokens = jnp.sum(counts, 0).astype(jnp.float32) / (t * k)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) * spec.aux_loss_weight
    return y, aux
