"""Wind-speed kriging over the Arabian-Peninsula-like domain (paper
Table I workflow): simulate a region's field from its Table-I Matern
parameters, re-estimate them, and cross-validate the prediction.

  PYTHONPATH=src python examples/wind_prediction.py --region R2
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, fit_mle, kfold_pmse, krige, make_loglik
from repro.covariance import WIND_REGIONS, wind_like_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--region", choices=list(WIND_REGIONS), default="R2")
ap.add_argument("--n", type=int, default=256)
args = ap.parse_args()

ds = wind_like_dataset(jax.random.PRNGKey(5), args.region, args.n)
theta0 = np.asarray(ds.theta0)
print(f"region {args.region}: n={args.n}, true theta = "
      f"({theta0[0]:.3f}, {theta0[1]:.3f}, {theta0[2]:.3f}) "
      f"[haversine degrees]")

pol = PrecisionPolicy.from_dp_percent(args.n // 32, 0.10)
ll = make_loglik(ds.locs, ds.z, pol, nb=32, metric="haversine")
res = fit_mle(ll, theta0 * np.array([0.8, 0.8, 1.0]), max_iters=50)
print(f"MP DP(10%)-SP(90%) estimate: ({res.theta[0]:.3f}, "
      f"{res.theta[1]:.3f}, {res.theta[2]:.3f})  "
      f"[{res.n_evals} likelihood evaluations]")

score, folds = kfold_pmse(ds.locs, ds.z, jnp.asarray(res.theta), pol,
                          k=4, nb=32, metric="haversine")
print(f"4-fold PMSE = {score:.4f} (per fold: "
      f"{', '.join(f'{s:.4f}' for s in folds)})")

# predict on a small grid for a "map"
obs = slice(0, (args.n // 32 - 1) * 32)
gx, gy = np.meshgrid(np.linspace(ds.locs[:, 0].min(), ds.locs[:, 0].max(), 8),
                     np.linspace(ds.locs[:, 1].min(), ds.locs[:, 1].max(), 8))
grid = jnp.asarray(np.stack([gx.ravel(), gy.ravel()], -1), jnp.float32)
mu = krige(ds.locs[obs], ds.z[obs], grid, jnp.asarray(res.theta), pol,
           nb=32, metric="haversine")
field = np.asarray(mu).reshape(8, 8)
print("kriged field (8x8 grid):")
for row in field:
    print("  " + " ".join(f"{v:6.2f}" for v in row))
