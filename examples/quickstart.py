"""Quickstart: fit a Matern field with the mixed-precision tile Cholesky
and predict held-out values -- the paper's pipeline in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, fit_mle, krige, make_loglik, pmse
from repro.covariance import make_dataset

N, NB = 256, 32

# 1. synthetic Matern field (medium correlation), Morton-ordered locations
ds = make_dataset(jax.random.PRNGKey(0), N, theta0=[1.0, 0.1, 0.5],
                  nu_static=0.5, ordering="morton")
# hold out every 8th point (spatially interleaved test set)
new = np.arange(7, N, 8)
obs = np.setdiff1d(np.arange(N), new)[:224]

# 2. maximum-likelihood fit with the paper's mixed-precision factorization
#    (hi=fp32 band around the diagonal, lo=bf16 off-band -- the TPU pair)
policy = PrecisionPolicy.tpu(diag_thick=2)
loglik = make_loglik(ds.locs[obs], ds.z[obs], policy, nb=NB, nu_static=0.5)
res = fit_mle(lambda th: loglik(jnp.concatenate([th, jnp.array([0.5])])),
              theta0=[0.7, 0.15], max_iters=60)
print(f"theta_hat = ({res.theta[0]:.3f}, {res.theta[1]:.4f})  "
      f"true = (1.0, 0.1)   loglik = {res.loglik:.2f}  "
      f"[{res.n_evals} evaluations]")

# 3. kriging prediction at unseen locations through the same factorization
theta_hat = jnp.array([res.theta[0], res.theta[1], 0.5])
mu, var = krige(ds.locs[obs], ds.z[obs], ds.locs[new], theta_hat, policy,
                nb=NB, nu_static=0.5, return_var=True)
print(f"prediction MSE = {float(pmse(mu, ds.z[new])):.4f}  "
      f"(mean kriging var = {float(var.mean()):.4f})")
