"""End-to-end LM training driver: data pipeline -> model -> AdamW ->
checkpointed fault-tolerant loop, with a loss-goes-down validation.

Default is a CPU-sized model for quick runs; --size 100m builds a ~100M-
parameter llama-style model (the assigned end-to-end target -- expect it
to be slow on 1 CPU core; on a TPU slice the same script just runs under
more devices).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300
"""

import argparse
import tempfile

import jax

from repro.data import DataConfig, SyntheticTokenSource
from repro.models.config import ArchConfig
from repro.runtime import FaultTolerantLoop, LoopConfig
from repro.train import TrainConfig, init_train_state, make_train_step

SIZES = {
    "tiny": dict(n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
                 d_head=16, d_ff=512, vocab=2048),
    "20m": dict(n_layers=8, d_model=384, n_heads=8, n_kv_heads=4,
                d_head=48, d_ff=1536, vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_head=64, d_ff=3072, vocab=32000),
}

ap = argparse.ArgumentParser()
ap.add_argument("--size", choices=list(SIZES), default="tiny")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--lr", type=float, default=1e-3)
ap.add_argument("--compression", choices=["none", "bf16", "int8"],
                default="none")
args = ap.parse_args()

cfg = ArchConfig(name=f"llama-style-{args.size}", family="dense",
                 rope_theta=5e5, remat=False, **SIZES[args.size])
tc = TrainConfig(peak_lr=args.lr, warmup=max(10, args.steps // 20),
                 total_steps=args.steps, compression=args.compression)
state, _ = init_train_state(jax.random.PRNGKey(0), cfg, tc)
n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
print(f"model: {n_params/1e6:.1f}M params, {jax.device_count()} device(s)")

src = SyntheticTokenSource(cfg, DataConfig(seed=0, global_batch=args.batch,
                                           seq_len=args.seq))
step = jax.jit(make_train_step(cfg, tc))

with tempfile.TemporaryDirectory() as ckpt_dir:
    loop = FaultTolerantLoop(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(50, args.steps // 4),
                   max_steps=args.steps),
        step, src, state)
    state = loop.run()

losses = [m["loss"] for m in loop.metrics_log]
k = max(1, len(losses) // 10)
first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
print(f"loss: {first:.4f} -> {last:.4f} over {len(losses)} steps "
      f"({'OK: decreasing' if last < first else 'WARNING: not decreasing'})")
