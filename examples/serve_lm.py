"""Serving example: prefill a prompt, then batched greedy decode -- with
the paper-inspired banded-precision KV option compared against exact.

  PYTHONPATH=src python examples/serve_lm.py --tokens 24
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.decode import decode_step, prefill
from repro.models.transformer import init_lm
from repro.kernels.mp_attention.ops import (banded_decode_attention,
                                            quantize_kv)

ap = argparse.ArgumentParser()
ap.add_argument("--tokens", type=int, default=16)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--batch", type=int, default=2)
args = ap.parse_args()

cfg = ArchConfig(name="serve-demo", family="dense", n_layers=4, d_model=128,
                 n_heads=8, n_kv_heads=4, d_head=16, d_ff=512, vocab=1024,
                 remat=False)
params, _ = init_lm(jax.random.PRNGKey(0), cfg)

prompt = jax.random.randint(jax.random.PRNGKey(1),
                            (args.batch, args.prompt_len), 0, cfg.vocab)
logits, cache = prefill(params, prompt, cfg)

# grow the cache for generation
grow = args.tokens
cache = jax.tree.map(
    lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, grow)] + [(0, 0)] * (x.ndim - 3))
    if x.ndim == 5 else x, cache)

step = jax.jit(lambda c, t, p: decode_step(params, c, t, p, cfg))
tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
out = [tok]
for i in range(args.tokens - 1):
    logits, cache = step(cache, tok, jnp.int32(args.prompt_len + i))
    tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    out.append(tok)
gen = jnp.concatenate(out, axis=1)
print("generated token ids:")
for b in range(args.batch):
    print(f"  seq{b}: {np.asarray(gen[b]).tolist()}")

# --- banded-precision KV attention demo (paper technique -> serving) ----
print("\nbanded-precision KV (near bf16 window + far int8 blocks):")
b, g, d, sn, sf = 2, 4, 64, 128, 256
ks = jax.random.split(jax.random.PRNGKey(2), 5)
q = jax.random.normal(ks[0], (b, g, d))
kn, vn = (jax.random.normal(k, (b, sn, d)) for k in ks[1:3])
kf, vf = (jax.random.normal(k, (b, sf, d)) for k in ks[3:5])
kq, vq, scales = quantize_kv(kf, vf)
near_len = jnp.full((b,), sn, jnp.int32)
far_len = jnp.full((b,), sf, jnp.int32)
out_mp = banded_decode_attention(q, kn, vn, near_len, kq, vq, scales,
                                 far_len, sm_scale=d ** -0.5)
# exact reference
k_all = jnp.concatenate([kn, kf], 1)
v_all = jnp.concatenate([vn, vf], 1)
p_ = jax.nn.softmax(jnp.einsum("bgd,bsd->bgs", q, k_all) * d ** -0.5, -1)
exact = jnp.einsum("bgs,bsd->bgd", p_, v_all)
err = float(jnp.max(jnp.abs(out_mp - exact)))
saved = 1 - (sn * 2 + sf * 1) / ((sn + sf) * 2)
print(f"  max error vs exact attention: {err:.2e}")
print(f"  far-segment cache bytes saved: {saved:.0%} "
      f"(decode is HBM-bound -> direct step-time win)")
