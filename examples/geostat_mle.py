"""Full geostatistics workflow: DP vs mixed-precision vs DST tapering.

Reproduces the paper's comparison end-to-end at CPU scale: simulate,
order, estimate with each precision policy, validate prediction accuracy.

Estimation runs on the batched evaluation engine (core/batch_engine.py):
a coarse batched grid search (every refinement level = ONE device call over
the whole candidate grid) seeds a speculative batched Nelder-Mead polish,
so the accelerator sees large batched tile ops instead of one tiny
factorization at a time.

  PYTHONPATH=src python examples/geostat_mle.py [--n 256] [--level medium]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (BatchEngine, BatchPlan, PrecisionPolicy, fit_mle,
                        fit_mle_grid, kfold_pmse)
from repro.covariance import CORRELATION_LEVELS, make_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=256)
ap.add_argument("--nb", type=int, default=32)
ap.add_argument("--level", choices=list(CORRELATION_LEVELS), default="medium")
ap.add_argument("--ordering", choices=["morton", "hilbert", "none"],
                default="morton")
ap.add_argument("--grid", type=int, default=8,
                help="grid-search resolution per parameter (batch = grid^2)")
ap.add_argument("--chunk", type=int, default=None,
                help="engine chunk size (bounds peak memory; None = one vmap)")
args = ap.parse_args()

theta0 = CORRELATION_LEVELS[args.level]
ds = make_dataset(jax.random.PRNGKey(1), args.n, theta0, nu_static=0.5,
                  ordering=args.ordering)
p = args.n // args.nb

policies = {
    "DP(100%)            ": PrecisionPolicy.full(jnp.float32),
    "DP(10%)-SP(90%)     ": PrecisionPolicy.from_dp_percent(p, 0.10),
    "DP(40%)-SP(60%)     ": PrecisionPolicy.from_dp_percent(p, 0.40),
    "three-tier fp32/bf16/fp8": PrecisionPolicy.three_tier(1, max(2, p // 2)),
    "DST DP(70%)-Zero    ": PrecisionPolicy.dst(
        PrecisionPolicy.from_dp_percent(p, 0.70).diag_thick),
}


print(f"n={args.n} level={args.level} true theta=({float(theta0[0])}, "
      f"{float(theta0[1])}, {float(theta0[2])}) ordering={args.ordering}")
print(f"{'variant':28s} {'var_hat':>8s} {'range_hat':>10s} "
      f"{'loglik':>10s} {'evals':>6s} {'pmse':>8s}")
for name, pol in policies.items():
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=pol, nb=args.nb, nu_static=0.5,
                                   chunk_size=args.chunk))
    # stage 1: batched grid search over (variance, range) -- the engine
    # appends the pinned nu column to (B, 2) candidates itself
    coarse = fit_mle_grid(engine.loglik, [(0.2, 5.0), (0.02, 0.6)],
                          num=args.grid, refine=2)
    # stage 2: speculative batched Nelder-Mead polish from the incumbent
    # (every evaluation runs through the engine; no sequential closure)
    res = fit_mle(None, coarse.theta, max_iters=50,
                  batched_loglik_fn=engine.loglik)
    n_evals = coarse.n_evals + res.n_evals
    try:
        score, _ = kfold_pmse(ds.locs, ds.z,
                              jnp.array([res.theta[0], res.theta[1], 0.5]),
                              pol if pol.mode != "dst"
                              else PrecisionPolicy.full(jnp.float32),
                              k=4, nb=args.nb, nu_static=0.5)
    except Exception:
        score = float("nan")
    print(f"{name:28s} {res.theta[0]:8.3f} {res.theta[1]:10.4f} "
          f"{res.loglik:10.2f} {n_evals:6d} {score:8.4f}")
