"""Full geostatistics workflow: DP vs mixed-precision vs DST tapering.

Reproduces the paper's comparison end-to-end at CPU scale: simulate,
order, estimate with each precision policy, validate prediction accuracy.

  PYTHONPATH=src python examples/geostat_mle.py [--n 256] [--level medium]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (PrecisionPolicy, fit_mle, kfold_pmse, make_loglik)
from repro.covariance import CORRELATION_LEVELS, make_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=256)
ap.add_argument("--nb", type=int, default=32)
ap.add_argument("--level", choices=list(CORRELATION_LEVELS), default="medium")
ap.add_argument("--ordering", choices=["morton", "hilbert", "none"],
                default="morton")
args = ap.parse_args()

theta0 = CORRELATION_LEVELS[args.level]
ds = make_dataset(jax.random.PRNGKey(1), args.n, theta0, nu_static=0.5,
                  ordering=args.ordering)
p = args.n // args.nb

policies = {
    "DP(100%)            ": PrecisionPolicy.full(jnp.float32),
    "DP(10%)-SP(90%)     ": PrecisionPolicy.from_dp_percent(p, 0.10),
    "DP(40%)-SP(60%)     ": PrecisionPolicy.from_dp_percent(p, 0.40),
    "three-tier fp32/bf16/fp8": PrecisionPolicy.three_tier(1, max(2, p // 2)),
    "DST DP(70%)-Zero    ": PrecisionPolicy.dst(
        PrecisionPolicy.from_dp_percent(p, 0.70).diag_thick),
}

print(f"n={args.n} level={args.level} true theta=({float(theta0[0])}, "
      f"{float(theta0[1])}, {float(theta0[2])}) ordering={args.ordering}")
print(f"{'variant':28s} {'var_hat':>8s} {'range_hat':>10s} "
      f"{'loglik':>10s} {'evals':>6s} {'pmse':>8s}")
for name, pol in policies.items():
    ll = make_loglik(ds.locs, ds.z, pol, nb=args.nb, nu_static=0.5)
    res = fit_mle(lambda th: ll(jnp.concatenate([th, jnp.array([0.5])])),
                  [0.7, 0.15], max_iters=50)
    try:
        score, _ = kfold_pmse(ds.locs, ds.z,
                              jnp.array([res.theta[0], res.theta[1], 0.5]),
                              pol if pol.mode != "dst"
                              else PrecisionPolicy.full(jnp.float32),
                              k=4, nb=args.nb, nu_static=0.5)
    except Exception:
        score = float("nan")
    print(f"{name:28s} {res.theta[0]:8.3f} {res.theta[1]:10.4f} "
          f"{res.loglik:10.2f} {res.n_evals:6d} {score:8.4f}")
