"""Paper Fig. 7: Monte-Carlo parameter-estimation accuracy boxplots.

Weak/medium/strong correlation x {DP, MP variants, DST variants}; N_REP
synthetic datasets per case (paper: 100 at n=40k; scaled to n=256/N_REP=6
for CPU -- the qualitative ordering DP ~ MP >> DST is the claim under
test; tests/test_mle_kriging.py asserts it)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, fit_mle, make_loglik
from repro.covariance import CORRELATION_LEVELS, make_dataset

from .common import emit

N = 256
NB = 32
N_REP = 6


def fit_variant(ds, policy, max_iters=40):
    ll = make_loglik(ds.locs, ds.z, policy, nb=NB, nu_static=0.5)
    f = lambda th: ll(jnp.concatenate([th, jnp.array([0.5])]))
    res = fit_mle(f, [0.7, 0.15], max_iters=max_iters)
    return res.theta, res.n_evals


def variants(p):
    return {
        "DP": PrecisionPolicy.full(jnp.float32),
        "DP10-SP90": PrecisionPolicy.from_dp_percent(p, 0.10),
        "DP40-SP60": PrecisionPolicy.from_dp_percent(p, 0.40),
        "DP90-SP10": PrecisionPolicy.from_dp_percent(p, 0.90),
        "DST-DP70": PrecisionPolicy.dst(
            PrecisionPolicy.from_dp_percent(p, 0.70).diag_thick),
        "DST-DP90": PrecisionPolicy.dst(
            PrecisionPolicy.from_dp_percent(p, 0.90).diag_thick),
    }


def run(n_rep=N_REP):
    p = N // NB
    results = {}
    for level, theta0 in CORRELATION_LEVELS.items():
        for vname, pol in variants(p).items():
            ests = []
            evals = []
            for rep in range(n_rep):
                ds = make_dataset(jax.random.fold_in(jax.random.PRNGKey(42),
                                                     rep * 7 + 1),
                                  N, theta0, nu_static=0.5)
                try:
                    th, ne = fit_variant(ds, pol)
                    ests.append(th)
                    evals.append(ne)
                except Exception:
                    continue
            if not ests:
                continue
            est = np.stack(ests)
            key = f"fig7/{level}/{vname}"
            results[key] = est
            emit(key, 0.0,
                 f"var_hat={est[:,0].mean():.3f}+-{est[:,0].std():.3f} "
                 f"range_hat={est[:,1].mean():.4f}+-{est[:,1].std():.4f} "
                 f"true=({float(theta0[0])} {float(theta0[1])}) "
                 f"evals={np.mean(evals):.0f}")
    return results


if __name__ == "__main__":
    run()
