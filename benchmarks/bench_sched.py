"""Dynamic-runtime suite: makespan/utilization across priorities x workers.

Two families of rows:

  sched_sim_*   -- the virtual-time backend over the tile DAG: for each
                   (policy, p, W, priority) cell the makespan (in the cost
                   model's bf16-equivalent nb^3 units, printed in the
                   us_per_call column as virtual units), utilization,
                   overlap fraction, and speedup over the W=1 sequential
                   baseline.  This is the paper's StarPU story in model
                   form: the mixed DAG keeps 4 workers >3x busy.

  sched_real_*  -- the threaded executor vs the sequential engine on one
                   real factorization: wall-clock per call plus a bitwise
                   equality flag against `tile_cholesky`.  Eager per-tile
                   dispatch costs far more than the engine's fused trace
                   (honest number, reported as sched_overhead) -- the real
                   backend exists for equivalence evidence, not speed.
"""

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, tile_cholesky
from repro.sched import SchedConfig, build_graph, simulate
from repro.sched.runtime import scheduled_tile_cholesky
from repro.verify.generators import spd_matrix

from .common import emit, time_call

_POLICIES = {
    "mixed": PrecisionPolicy.tpu(2),
    "three_tier": PrecisionPolicy.three_tier(1, 3),
}
_PRIORITIES = ("fifo", "panel_first", "critical_path")
_WORKERS = (1, 2, 4, 8)


def run() -> None:
    for label, pol in _POLICIES.items():
        for p in (8, 16):
            graph = build_graph("tile", p, pol)
            base = simulate(graph, SchedConfig(priority="fifo", workers=1,
                                               backend="sim"))
            for priority in _PRIORITIES:
                for w in _WORKERS:
                    rep = simulate(graph, SchedConfig(priority=priority,
                                                      workers=w,
                                                      backend="sim"))
                    emit(f"sched_sim_{label}_p{p}_{priority}_w{w}",
                         rep.makespan,
                         f"tasks={rep.n_tasks}"
                         f";makespan={rep.makespan:.1f}"
                         f";util={rep.utilization:.3f}"
                         f";overlap={rep.overlap_fraction:.3f}"
                         f";speedup_vs_w1={base.makespan / rep.makespan:.2f}")

    # real threaded executor vs the sequential engine, one representative cell
    pol = PrecisionPolicy.tpu(2)
    n, nb = 128, 16
    a = spd_matrix(0, n, cond=100.0)
    seq_fn = jax.jit(lambda x: tile_cholesky(x, nb, pol))
    seq_us = time_call(seq_fn, a)
    cfg = SchedConfig(priority="critical_path", workers=4)
    l_sched, rep = scheduled_tile_cholesky(a, nb, pol, cfg)
    t0 = __import__("time").perf_counter()
    l_sched, rep = scheduled_tile_cholesky(a, nb, pol, cfg)
    real_us = (__import__("time").perf_counter() - t0) * 1e6
    # bitwise flag vs the EAGER engine: jit fuses tile ops and may round
    # differently, so the equivalence claim is eager-vs-eager
    bitwise = bool(jnp.all(l_sched == tile_cholesky(a, nb, pol)))
    emit(f"sched_real_mixed_n{n}", real_us,
         f"seq_us={seq_us:.1f};sched_overhead={real_us / seq_us:.1f}x"
         f";bitwise={bitwise};workers={cfg.workers}"
         f";util={rep.utilization:.3f}")
