"""LM roofline table: all (arch x shape) cells on the production meshes.

Primary terms come from the analytic cost model (always available); when
the dry-run sweep has produced results/dryrun/*.json, the compiled-module
numbers (peak memory, collective parse, compile time) are merged in."""

import glob
import json
import os

from repro.configs import ALL_ARCHS, SHAPES, cell_applicable
from repro.launch.costmodel import lm_cell_cost
from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_BF16_FLOPS

from .common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_dryrun(arch, shape, mesh):
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def run(mesh="single"):
    chips = 256 if mesh == "single" else 512
    mesh_axes = ({"data": 16, "model": 16} if mesh == "single"
                 else {"pod": 2, "data": 16, "model": 16})
    rows = []
    for arch, cfg in ALL_ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, why = cell_applicable(cfg, shape)
            if not ok:
                emit(f"roofline/{arch}/{sname}/{mesh}", 0.0, "SKIP:" + why[:40])
                continue
            from repro.launch.dryrun import TRAIN_OVERRIDES, arch_for_cell
            mb = (TRAIN_OVERRIDES.get(arch, {}).get("microbatches", 1)
                  if shape.kind == "train" else 1)
            cc = lm_cell_cost(arch_for_cell(arch), shape, chips=chips,
                              mesh_axes=mesh_axes, microbatches=mb)
            t_c = cc.flops / chips / PEAK_BF16_FLOPS
            t_m = cc.hbm_bytes / chips / HBM_BW
            t_n = cc.collective_bytes_per_chip / ICI_LINK_BW
            bound = max(t_c, t_m, t_n)
            useful = (cc.model_flops / chips) / PEAK_BF16_FLOPS
            dr = load_dryrun(arch, sname, mesh)
            extra = ""
            if dr:
                peak = dr["extras"]["peak_bytes_per_chip"] / 2 ** 30
                extra = (f" peak={peak:.1f}GiB fits="
                         f"{dr['extras']['fits_hbm']} "
                         f"compile={dr['extras']['compile_s']}s")
            emit(f"roofline/{arch}/{sname}/{mesh}", bound * 1e6,
                 f"tc={t_c*1e3:.1f}ms tm={t_m*1e3:.1f}ms tn={t_n*1e3:.1f}ms "
                 f"bneck={'cmn'[int(np.argmax([t_c, t_m, t_n]))]} "
                 f"roofline_frac={useful/bound:.3f}{extra}")
            rows.append((arch, sname, t_c, t_m, t_n, useful / bound))
    return rows


import numpy as np  # noqa: E402

if __name__ == "__main__":
    run()
