"""Paper Table I: wind-speed dataset, 4 regions -- estimation + PMSE.

The real Middle-East WRF data is not redistributable; we simulate each
region from the Table-I Matern parameters (haversine metric, general
smoothness ~1.1-1.4 via the Bessel path) and re-estimate with DP / MP /
DST, mirroring the table's structure (DESIGN.md changed-assumptions)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, fit_mle, kfold_pmse, make_loglik
from repro.covariance import WIND_REGIONS, wind_like_dataset

from .common import emit

N = 256
NB = 32


def run(regions=("R1", "R2", "R3", "R4")):
    p = N // NB
    rows = {}
    for region in regions:
        ds = wind_like_dataset(jax.random.PRNGKey(5), region, N)
        theta0 = np.asarray(ds.theta0)
        for vname, pol in [
            ("DP", PrecisionPolicy.full(jnp.float32)),
            ("MP10-90", PrecisionPolicy.from_dp_percent(p, 0.10)),
            ("MP90-10", PrecisionPolicy.from_dp_percent(p, 0.90)),
        ]:
            ll = make_loglik(ds.locs, ds.z, pol, nb=NB, metric="haversine")
            res = fit_mle(ll, theta0 * np.array([0.8, 0.8, 1.0]),
                          max_iters=40)
            score, _ = kfold_pmse(ds.locs, ds.z, jnp.asarray(res.theta),
                                  pol, k=4, nb=NB, metric="haversine")
            rows[(region, vname)] = (res.theta, score)
            emit(f"table1/{region}/{vname}", 0.0,
                 f"theta_hat=({res.theta[0]:.2f} {res.theta[1]:.2f} "
                 f"{res.theta[2]:.3f}) true=({theta0[0]:.2f} {theta0[1]:.2f} "
                 f"{theta0[2]:.3f}) pmse={score:.4f} iters={res.n_iters}")
    return rows


if __name__ == "__main__":
    run()
