"""Kernel-level benches: correctness deltas + analytic tile rooflines.

interpret=True wall-clock on CPU is not a TPU proxy; instead we report the
kernels' analytic VMEM footprint and arithmetic intensity (the quantities
BlockSpec tiling controls) plus the numerical error vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.covariance import random_locations
from repro.kernels.matern_cov.ops import matern_cov
from repro.kernels.matern_cov.ref import matern_cov_ref
from repro.kernels.mp_gemm.ops import mp_syrk
from repro.kernels.mp_gemm.ref import mp_syrk_ref
from repro.kernels.mp_attention.ops import banded_decode_attention, quantize_kv
from repro.kernels.mp_attention.ref import banded_decode_attention_ref

from .common import emit


def run():
    # matern_cov: VMEM per (128,128) tile = out 64KiB + locs 2KiB
    la = random_locations(jax.random.PRNGKey(0), 256)
    theta = jnp.array([1.0, 0.1, 0.5])
    out = matern_cov(la, la, theta, nu=0.5)
    ref = matern_cov_ref(la, la, theta, nu=0.5)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernels/matern_cov", 0.0,
         f"max_err={err:.2e} vmem_tile=66KiB ai=~25flop/B")

    # mp_syrk: off-band bf16 MXU dot = the paper's sgemm at 8x fp32 rate
    p = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    out = mp_syrk(p, band_blocks=1, bm=64, bk=64)
    ref = mp_syrk_ref(p, band_blocks=1, bm=64, bk=64)
    err = float(jnp.max(jnp.abs(out - ref)))
    offband_frac = 1 - (4 * 64 - 6) / (4 * 5 / 2 + 4 * 3)  # illustrative
    emit("kernels/mp_syrk", 0.0,
         f"max_err={err:.2e} vmem_tile=3x32KiB "
         f"offband_bf16_rate=8x_fp32_mxu")

    # mp_attention: int8 far cache halves decode bytes
    b, g, d, sn, sf = 2, 4, 64, 128, 256
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (b, g, d))
    kn = jax.random.normal(ks[1], (b, sn, d))
    vn = jax.random.normal(ks[2], (b, sn, d))
    kf = jax.random.normal(ks[3], (b, sf, d))
    vf = jax.random.normal(ks[4], (b, sf, d))
    kq, vq, sc = quantize_kv(kf, vf)
    nl = jnp.full((b,), sn, jnp.int32)
    fl = jnp.full((b,), sf, jnp.int32)
    out = banded_decode_attention(q, kn, vn, nl, kq, vq, sc, fl,
                                  sm_scale=d ** -0.5)
    ref = banded_decode_attention_ref(q, kn, vn, nl, kq, vq, sc, fl,
                                      sm_scale=d ** -0.5)
    err = float(jnp.max(jnp.abs(out - ref)))
    bytes_bf16 = (sn + sf) * d * 2 * 2
    bytes_mp = sn * d * 2 * 2 + sf * d * 1 * 2
    emit("kernels/mp_attention", 0.0,
         f"max_err_vs_oracle={err:.2e} "
         f"cache_bytes_reduction={100*(1-bytes_mp/bytes_bf16):.0f}%")


if __name__ == "__main__":
    run()
