"""Batched likelihood engine throughput vs the sequential path.

The batched engine's claim: B likelihood evaluations as ONE device call
(all tile ops carry a leading batch axis) beat B sequential jitted calls,
because per-eval dispatch + host-sync overhead amortizes and the pairwise
distance matrix is computed once per batch instead of once per candidate.
The sequential baseline is the pre-engine optimizer loop from
`core/mle.py`: one jitted call and one host sync per candidate
(`BatchEngine.loglik_sequential`).

Timing interleaves the two paths (min over rounds) so background load
drift on a shared box hits both equally.  Large batches run chunked
(`BatchPlan.chunk_size`) so the B x n x n covariance stacks stay
cache-resident.

  PYTHONPATH=src python -m benchmarks.run batch
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BatchEngine, BatchPlan, PrecisionPolicy
from repro.covariance import make_dataset

from .common import emit

N = 256
NB = 16
CHUNK = 16
BATCH_SIZES = (1, 2, 4, 8, 16, 32)
ROUNDS = 8


def candidate_thetas(b: int):
    """Deterministic log-spaced candidates around the true parameters."""
    t1 = np.geomspace(0.5, 2.0, b)
    t2 = np.geomspace(0.04, 0.3, b)
    nu = np.full(b, 0.5)
    return jnp.asarray(np.stack([t1, t2, nu], axis=-1), dtype=jnp.float32)


def policies(p: int):
    t = max(2, p // 4)
    return {
        "full": PrecisionPolicy.full(jnp.float32),
        # TPU-native pair (bf16 off-band); bf16 is emulated on CPU, which
        # slows BOTH paths equally, so the speedup ratio stays meaningful
        "mixed": PrecisionPolicy.tpu(t),
        # fp32/fp32 pair: the paper's hi/lo structure with both tiers fp32
        # (x64-free CPU stand-in) -- the row the ll-agreement check targets
        "mixed_fp32": PrecisionPolicy(mode="mixed", hi=jnp.float32,
                                      lo=jnp.float32, diag_thick=t),
        "dst": PrecisionPolicy.dst(t),
    }


def _interleaved_min(fn_a, fn_b, rounds=ROUNDS):
    """min wall-clock seconds of each fn, alternating A/B per round."""
    ta, tb = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)


def run(n: int = N, nb: int = NB, batch_sizes=BATCH_SIZES, chunk: int = CHUNK):
    ds = make_dataset(jax.random.PRNGKey(11), n, [1.0, 0.1, 0.5],
                      nu_static=0.5)
    rows = []
    for name, pol in policies(n // nb).items():
        engine = BatchEngine(ds.locs, ds.z,
                             BatchPlan(policy=pol, nb=nb, nu_static=0.5,
                                       chunk_size=chunk))
        for b in batch_sizes:
            thetas = candidate_thetas(b)

            def seq(ths=thetas):
                return engine.loglik_sequential(ths)

            def bat(ths=thetas):
                return jax.block_until_ready(engine.loglik(ths))

            ll_seq = np.asarray(seq(), dtype=np.float64)   # also warmup
            ll_bat = np.asarray(bat(), dtype=np.float64)
            t_seq, t_bat = _interleaved_min(seq, bat)
            eps_seq = b / t_seq
            eps_bat = b / t_bat
            rel = float(np.max(np.abs(ll_bat - ll_seq) / np.abs(ll_seq)))
            emit(f"batch/{name}/B{b}", t_bat * 1e6,
                 f"seq_evals_per_s={eps_seq:.1f};bat_evals_per_s={eps_bat:.1f};"
                 f"speedup={eps_bat / eps_seq:.2f}x;max_rel_diff={rel:.2e}")
            rows.append((name, b, eps_seq, eps_bat, rel))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
