"""Shared benchmark utilities."""

import time

import jax
import numpy as np


def time_call(fn, *args, warmup=1, iters=3):
    """Median wall-clock microseconds per call of a jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
