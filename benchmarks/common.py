"""Shared benchmark utilities."""

import time

import jax
import numpy as np


def predicted_flop_mix(n: int, nb: int, policy, variant: str | None = None) -> str:
    """Derived-column fragment with the static DAG's per-tier FLOP mix.

    The repro.analysis tile-DAG checker counts every POTRF/TRSM/SYRK/GEMM
    the engine will emit, per execution tier -- so perf rows can print the
    achieved numbers next to the statically predicted mix and a routing
    regression (e.g. a band tile silently taking the lo path) shows up as
    a mismatch, not just a timing blip.
    """
    from repro.analysis.dag import flop_report

    if variant is None:
        variant = "dst" if policy.mode == "dst" else "tile"
    rep = flop_report(n, nb, policy, variant)
    return (f"pred_hi_frac={rep['hi_frac']:.3f}"
            f";pred_lo_frac={rep['lo_frac'] + rep['lo2_frac']:.3f}"
            f";pred_flops={rep['total_flops']:.3e}"
            f";cp_tasks={int(rep['critical_path_tasks'])}")


def xla_flops(fn, *args) -> float | None:
    """Compiled-module FLOP count, or None where cost_analysis is missing."""
    try:
        cost = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost["flops"])
    except Exception:
        return None


def time_call(fn, *args, warmup=1, iters=3):
    """Median wall-clock microseconds per call of a jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
