"""Paper Fig. 4: execution time per likelihood iteration, DP vs
mixed-precision variants, shared-memory (this CPU).

Faithful regime: the paper's literal pair (DP=fp64 band, SP=fp32 off-band)
under x64 -- on CPU fp32 GEMMs genuinely run ~2x fp64, so the paper's
speedup mechanism is measurable here (the TPU fp32/bf16 pair is evaluated
via the roofline model in bench_fig6/bench_lm_roofline)."""

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, make_loglik
from repro.covariance import make_dataset

from .common import emit, time_call


def run(ns=(256, 512, 1024), nb=64):
    rows = []
    with jax.experimental.enable_x64():
        for n in ns:
            ds = make_dataset(jax.random.PRNGKey(0), n, [1.0, 0.1, 0.5],
                              nu_static=0.5)
            theta = jnp.asarray(ds.theta0, jnp.float64)
            t_dp = time_call(jax.jit(make_loglik(
                ds.locs, ds.z, PrecisionPolicy.full(jnp.float64), nb=nb,
                nu_static=0.5, use_tiles=True)), theta)
            p = n // nb
            for dp_pct in (0.1, 0.4, 0.9):
                pol = PrecisionPolicy.from_dp_percent(p, dp_pct,
                                                      pair="paper_cpu")
                t_mp = time_call(jax.jit(make_loglik(
                    ds.locs, ds.z, pol, nb=nb, nu_static=0.5)), theta)
                label = f"fig4/n{n}/DP{int(dp_pct*100)}%-SP{100-int(dp_pct*100)}%"
                emit(label, t_mp, f"speedup_vs_DP={t_dp/t_mp:.2f}x")
                rows.append((n, dp_pct, t_dp, t_mp))
            emit(f"fig4/n{n}/DP100%", t_dp, "baseline")
    return rows


if __name__ == "__main__":
    run()
