# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

One suite per paper figure/table plus framework extras.  The suite table
lives in SUITES below -- the one source of truth; `--list` (and the
header this module prints on bad input) is generated from it, so the help
text can no longer drift from the registry the way the old hand-written
docstring enumeration did.

Run a subset: python -m benchmarks.run fig4 fig7
List suites:  python -m benchmarks.run --list
Telemetry:    python -m benchmarks.run fig4 --metrics out/metrics.jsonl
              (JSONL event log at that path, human summary table next to
              it as <path>.summary.txt; see DESIGN.md §13)
"""

import sys
import traceback

# name -> (module under benchmarks/, one-line description)
SUITES = {
    "fig4": ("bench_fig4_shared_memory",
             "shared-memory time per likelihood iteration (fp64 vs fp64/fp32)"),
    "fig5": ("bench_fig5_data_movement",
             "data-movement / storage bytes, DP vs mixed precision"),
    "fig6": ("bench_fig6_scalability",
             "distributed scalability 64 -> 512 chips (roofline model)"),
    "fig7": ("bench_fig7_estimation",
             "Monte-Carlo parameter-estimation accuracy"),
    "fig8": ("bench_fig8_pmse",
             "k-fold PMSE per precision variant"),
    "table1": ("bench_table1_real",
               "wind-speed (WRF-like) regions: estimation + PMSE"),
    "batch": ("bench_batched_mle",
              "batched likelihood engine throughput vs sequential path"),
    "lm": ("bench_lm_roofline",
           "40-cell (arch x shape) roofline table"),
    "kernels": ("bench_kernels",
                "Pallas kernel correctness/footprint summary"),
    "accuracy": ("bench_accuracy",
                 "oracle-measured accuracy columns next to perf (repro.verify)"),
    "sched": ("bench_sched",
              "dynamic-runtime makespan/utilization across priorities x workers"),
}


def suite_table() -> str:
    width = max(len(name) for name in SUITES)
    lines = [f"  {name:<{width}}  {desc}" for name, (_, desc) in SUITES.items()]
    return "Suites:\n" + "\n".join(lines)


def _resolve(name: str):
    import importlib
    module, _ = SUITES[name]
    return importlib.import_module(f".{module}", package=__package__).run


def _pop_metrics_path(args: list) -> str | None:
    """Extract `--metrics <path>` (or `--metrics=<path>`) from args."""
    for i, a in enumerate(args):
        if a == "--metrics":
            if i + 1 >= len(args):
                print("--metrics requires a path", file=sys.stderr)
                sys.exit(2)
            path = args[i + 1]
            del args[i:i + 2]
            return path
        if a.startswith("--metrics="):
            del args[i]
            return a.split("=", 1)[1]
    return None


def main() -> None:
    args = sys.argv[1:]
    if any(a in ("--list", "-h", "--help") for a in args):
        print(__doc__.strip())
        print()
        print(suite_table())
        return
    metrics_path = _pop_metrics_path(args)
    unknown = [a for a in args if a not in SUITES]
    if unknown:
        print(f"unknown suite(s): {unknown}", file=sys.stderr)
        print(suite_table(), file=sys.stderr)
        sys.exit(2)
    wanted = args or list(SUITES)

    recording = None
    if metrics_path is not None:
        from repro import obs
        recording = obs.recording()
        recording.__enter__()

    print("name,us_per_call,derived")
    failures = []
    try:
        for name in wanted:
            try:
                _resolve(name)()
            except Exception:
                failures.append(name)
                traceback.print_exc()
    finally:
        if recording is not None:
            from repro import obs
            rec = obs.get_recorder()
            obs.write_jsonl(rec, metrics_path)
            summary = obs.summary_table(rec)
            with open(f"{metrics_path}.summary.txt", "w") as fh:
                fh.write(summary + "\n")
            recording.__exit__(None, None, None)
            print(f"# metrics: {metrics_path} "
                  f"(+ {metrics_path}.summary.txt)", file=sys.stderr)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
