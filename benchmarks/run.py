# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper figure/table + framework extras.

  fig4   shared-memory time per likelihood iteration (fp64 vs fp64/fp32)
  fig5   data-movement / storage bytes, DP vs mixed precision
  fig6   distributed scalability 64 -> 512 chips (roofline model)
  fig7   Monte-Carlo parameter-estimation accuracy
  fig8   k-fold PMSE per precision variant
  table1 wind-speed (WRF-like) regions: estimation + PMSE
  batch  batched likelihood engine throughput vs sequential path
  lm     40-cell (arch x shape) roofline table
  kernels Pallas kernel correctness/footprint summary
  accuracy oracle-measured accuracy columns next to perf (repro.verify)

Run a subset: python -m benchmarks.run fig4 fig7
"""

import sys
import traceback


def main() -> None:
    from . import (bench_accuracy, bench_batched_mle,
                   bench_fig4_shared_memory, bench_fig5_data_movement,
                   bench_fig6_scalability, bench_fig7_estimation,
                   bench_fig8_pmse, bench_kernels, bench_lm_roofline,
                   bench_table1_real)

    suites = {
        "fig4": bench_fig4_shared_memory.run,
        "fig5": bench_fig5_data_movement.run,
        "fig6": bench_fig6_scalability.run,
        "fig7": bench_fig7_estimation.run,
        "fig8": bench_fig8_pmse.run,
        "table1": bench_table1_real.run,
        "batch": bench_batched_mle.run,
        "lm": bench_lm_roofline.run,
        "kernels": bench_kernels.run,
        "accuracy": bench_accuracy.run,
    }
    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failures = []
    for name in wanted:
        try:
            suites[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
