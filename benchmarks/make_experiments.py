"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from
results/dryrun/*.json.  Run after `python -m repro.launch.dryrun --all`.

  PYTHONPATH=src:. python -m benchmarks.make_experiments > results/tables.md
"""

import glob
import json
import os
import sys

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")
PERF_DIR = os.environ.get("PERF_DIR", "results/perf")


def load_all():
    cells = {}
    for d_ in (DRYRUN_DIR, PERF_DIR):
        for path in sorted(glob.glob(os.path.join(d_, "*.json"))):
            with open(path) as f:
                d = json.load(f)
            base = os.path.basename(path)[:-5]
            cells[base] = d
    return cells


def fmt_t(sec):
    return f"{sec*1e3:.1f}" if sec < 10 else f"{sec*1e3:.0f}"


def roofline_table(cells, mesh="single", variants=False):
    print(f"\n### Roofline — {mesh}-pod "
          f"({'variants' if variants else 'baselines'})\n")
    print("| cell | t_compute (ms) | t_memory (ms) | t_collective (ms) |"
          " bottleneck | useful/HLO | roofline frac | peak GiB | fits |"
          " compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for name, d in sorted(cells.items()):
        parts = name.split("__")
        if len(parts) != 3:
            continue
        arch, shape, meshv = parts
        is_variant = "+" in meshv
        if not meshv.startswith(mesh) or is_variant != variants:
            continue
        ex = d["extras"]
        print(f"| {arch}:{shape}{meshv[len(mesh):]} | {fmt_t(d['t_compute'])} "
              f"| {fmt_t(d['t_memory'])} | {fmt_t(d['t_collective'])} "
              f"| {d['bottleneck']} | {d['useful_flops_fraction']:.2f} "
              f"| {d['roofline_fraction']:.3f} "
              f"| {ex['peak_bytes_per_chip']/2**30:.1f} "
              f"| {'Y' if ex['fits_hbm'] else 'N'} "
              f"| {ex['compile_s']:.0f} |")


def dryrun_summary(cells):
    n_single = sum(1 for k in cells if k.endswith("__single"))
    n_multi = sum(1 for k in cells if k.endswith("__multi"))
    fits = sum(1 for d in cells.values() if d["extras"]["fits_hbm"])
    print(f"\nCompiled cells: {n_single} single-pod + {n_multi} multi-pod; "
          f"{fits}/{len(cells)} within the 16 GiB/chip estimate "
          f"(CPU-backend f32-inflated; see Methodology).")
    worst = sorted(((d["roofline_fraction"], k) for k, d in cells.items()
                    if k.endswith("__single")))
    if worst:
        print(f"\nWorst roofline fractions (hillclimb candidates): "
              f"{[(k, round(f, 3)) for f, k in worst[:4]]}")


def main():
    cells = load_all()
    dryrun_summary(cells)
    roofline_table(cells, "single")
    roofline_table(cells, "multi")
    roofline_table(cells, "single", variants=True)


if __name__ == "__main__":
    main()
