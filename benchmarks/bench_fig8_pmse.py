"""Paper Fig. 8: Prediction MSE (k-fold CV) boxplots per variant.

MP variants should match DP's PMSE at every correlation level while DST
degrades unless ~90% of tiles are dense (the paper's central prediction
claim)."""

import jax
import numpy as np

import jax.numpy as jnp

from repro.core import PrecisionPolicy, kfold_pmse, krige, pmse
from repro.covariance import CORRELATION_LEVELS, make_dataset

from .common import emit

N = 256
NB = 32
K = 4


def dst_pmse(ds, diag_thick, k=K, seed=0):
    """DST prediction: kriging through the block-diagonal covariance ==
    kriging with only the super-block containing each target."""
    n = ds.locs.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    fold = n // k
    super_nb = diag_thick * NB
    pol = PrecisionPolicy.full(jnp.float32)
    scores = []
    for f in range(k):
        test = perm[f * fold:(f + 1) * fold]
        train = np.setdiff1d(perm, test)[: (n - fold) // NB * NB]
        preds = np.zeros(len(test))
        # each test point predicted from its own block only
        for s in range(0, len(train), super_nb):
            blk = train[s:s + super_nb]
            if len(blk) < NB:
                continue
            blk = blk[: len(blk) // NB * NB]
            mu = krige(ds.locs[blk], ds.z[blk], ds.locs[test], ds.theta0,
                       pol, nb=NB, nu_static=0.5)
            # nearest-block assignment: weight by max cross-covariance
            d = np.linalg.norm(np.asarray(ds.locs[test])[:, None]
                               - np.asarray(ds.locs[blk])[None], axis=-1)
            preds = np.where(d.min(1) < (np.abs(preds) * 0 + 0.08),
                             np.asarray(mu), preds)
        scores.append(float(np.mean((preds - np.asarray(ds.z[test])) ** 2)))
    return float(np.mean(scores))


def run():
    p = N // NB
    out = {}
    for level, theta0 in CORRELATION_LEVELS.items():
        ds = make_dataset(jax.random.PRNGKey(11), N, theta0, nu_static=0.5)
        for vname, pol in [
            ("DP", PrecisionPolicy.full(jnp.float32)),
            ("DP10-SP90", PrecisionPolicy.from_dp_percent(p, 0.10)),
            ("DP40-SP60", PrecisionPolicy.from_dp_percent(p, 0.40)),
        ]:
            score, _ = kfold_pmse(ds.locs, ds.z, theta0, pol, k=K, nb=NB,
                                  nu_static=0.5)
            out[f"{level}/{vname}"] = score
            emit(f"fig8/{level}/{vname}", 0.0, f"pmse={score:.4f}")
        d70 = dst_pmse(ds, PrecisionPolicy.from_dp_percent(p, 0.70).diag_thick)
        out[f"{level}/DST-DP70"] = d70
        emit(f"fig8/{level}/DST-DP70", 0.0, f"pmse={d70:.4f}")
    return out


if __name__ == "__main__":
    run()
