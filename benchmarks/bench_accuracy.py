"""Accuracy columns next to perf numbers (verify/ conformance sweep).

Emits one row per precision variant on the n=192 medium-correlation
problem -- wall-clock per factorization in the `us_per_call` column and the
oracle-measured accuracy metrics in `derived` -- plus per-suite summary
rows for the kernel pairs.  This is the benchmark-facing face of
`repro.verify`: the same generators and oracles the conformance tests
gate on, so a perf PR that moves accuracy shows it here first.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tile_cholesky
from repro.core.likelihood import loglik_from_factor
from repro.verify import (
    exact_factor,
    exact_loglik,
    loglik_drift,
    matern_problem,
    rel_frobenius,
    sweep_kernels,
)
from repro.verify.bounds import dtype_pair, policy_bound
from repro.verify.conformance import default_policies

from .common import emit, predicted_flop_mix, time_call, xla_flops


def run() -> None:
    prob = matern_problem(192, "medium")
    l_ref = exact_factor(prob.cov)
    ll_ref = exact_loglik(prob.cov, prob.z)

    for label, pol in default_policies().items():
        cov = prob.cov.astype(pol.hi)
        fn = jax.jit(lambda a, p=pol: tile_cholesky(a, prob.nb, p))
        us = time_call(fn, cov)
        l = np.asarray(fn(cov), np.float64)
        ll = float(loglik_from_factor(jnp.asarray(l, jnp.float32), prob.z))
        bound = policy_bound(pol, prob.regime)
        # achieved (XLA-counted) FLOPs next to the static DAG prediction:
        # a tile silently routed to the wrong tier moves the ratio, not
        # just the timing column
        mix = predicted_flop_mix(prob.n, prob.nb, pol)
        achieved = xla_flops(lambda a, p=pol: tile_cholesky(a, prob.nb, p), cov)
        if achieved is not None:
            mix += f";xla_flops={achieved:.3e}"
        emit(f"acc_chol_{label}_{prob.name}", us,
             f"pair={dtype_pair(pol)};factor_rel={rel_frobenius(l, l_ref):.2e}"
             f";loglik_drift={loglik_drift(ll, ll_ref):.2e}"
             f";factor_bound={bound.factor_rel:.0e};{mix}")

    # kernel pairs: worst measured error per kernel across the sweep grid
    worst: dict[str, float] = {}
    for rec in sweep_kernels():
        err = rec.get("max_rel", rec.get("max_abs", 0.0))
        worst[rec["kernel"]] = max(worst.get(rec["kernel"], 0.0), err)
    for kernel, err in sorted(worst.items()):
        emit(f"acc_kernel_{kernel}", 0.0, f"worst_err={err:.2e}")
