"""Paper Fig. 5: data-movement cost, DP vs mixed-precision.

The paper measures CPU<->GPU transfer volume; the TPU analogue is the
HBM/ICI byte footprint of the covariance storage.  We report the exact
storage bytes of the split (band hi / off-band lo) layout vs full-DP --
the paper observes 40-60% reduction; the packed layout gives
1 - [band*4 + off*2] / [n^2/2 * 4] for the fp32/bf16 pair."""

import numpy as np

from repro.core import PrecisionPolicy

from .common import emit


def storage_bytes(n, nb, t, hi_bytes, lo_bytes):
    p = n // nb
    t = min(t, p)
    band_tiles = t * p - t * (t - 1) // 2
    total_tiles = p * (p + 1) // 2
    off_tiles = total_tiles - band_tiles
    band = band_tiles * nb * nb * hi_bytes
    off = off_tiles * nb * nb * lo_bytes
    return band, off


def run(ns=(16384, 131072, 524288), nb=2048):
    for n in ns:
        p = n // nb
        dp = (p * (p + 1) // 2) * nb * nb * 4
        for dp_pct in (0.1, 0.4, 0.9):
            pol = PrecisionPolicy.from_dp_percent(p, dp_pct)
            band, off = storage_bytes(n, nb, pol.diag_thick, 4, 2)
            mp = band + off
            red = 100.0 * (1 - mp / dp)
            emit(f"fig5/n{n}/DP{int(dp_pct*100)}%", 0.0,
                 f"bytes={mp/2**30:.2f}GiB reduction={red:.0f}% "
                 f"(DP={dp/2**30:.2f}GiB)")


if __name__ == "__main__":
    run()
