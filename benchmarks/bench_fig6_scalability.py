"""Paper Fig. 6: distributed scalability, 64 -> 512 chips.

Roofline-model time per likelihood iteration on TPU v5e meshes (the CPU
container cannot time 512 chips; the model uses the same constants as
EXPERIMENTS.md §Roofline).  DP(100%) vs the mixed-precision band: the MP
speedup comes from bf16 off-band MXU throughput + halved off-band bytes,
exactly the mechanism the paper measures with fp64/fp32 on Shaheen-II."""

from repro.launch.costmodel import geostat_cell_cost
from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_BF16_FLOPS

from .common import emit


def model_time(cost, chips):
    t_comp = cost.flops / chips / PEAK_BF16_FLOPS
    t_mem = cost.hbm_bytes / chips / HBM_BW
    t_coll = cost.collective_bytes_per_chip / ICI_LINK_BW
    return max(t_comp, t_mem, t_coll), (t_comp, t_mem, t_coll)


def run(n=524_288, nb=8192):
    for chips in (64, 128, 256, 512):
        mp = geostat_cell_cost(n, nb, diag_thick=8, chips=chips)
        # DP(100%): every tile fp32 (6x MXU cost), full fp32 bytes
        dp = geostat_cell_cost(n, nb, diag_thick=n // nb, chips=chips)
        t_mp, terms = model_time(mp, chips)
        t_dp, _ = model_time(dp, chips)
        emit(f"fig6/chips{chips}", t_mp * 1e6,
             f"dp_time={t_dp:.2f}s mp_time={t_mp:.2f}s "
             f"speedup={t_dp/t_mp:.2f}x terms=c{terms[0]:.2f}/m{terms[1]:.2f}"
             f"/n{terms[2]:.2f}s")


if __name__ == "__main__":
    run()
