"""Real-executor equivalence matrix: out-of-order == sequential, bitwise.

The acceptance gate for the dynamic runtime (DESIGN.md §12): executing an
engine's task DAG on a threaded worker pool must reproduce the sequential
engine's tile values *bitwise* -- not allclose -- for every cell of the
(variant x full/mixed/three_tier x p in {1, 4, 8}) conformance matrix.
The runtime earns this by construction (write-once values keyed by
producer index), and these tests pin it empirically.

One deliberate exception: `dst_cholesky` factors each super-block with one
dense LAPACK Cholesky, while the DAG executes tile-level right-looking
steps inside the block.  For single-tile blocks the two coincide exactly;
for multi-tile blocks the blocking differs algorithmically, so the gate
there is (a) out-of-order bitwise-equal to in-order replay of the same
DAG, and (b) allclose to the dense-block reference.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, HYPOTHESIS_SKIP_REASON

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    import strategies as sts

from repro.core import PrecisionPolicy, tile_cholesky
from repro.core.panel_cholesky import panel_cholesky_banded
from repro.core.tile_cholesky import dst_cholesky, split_tiles
from repro.sched import SchedConfig, scheduled_cholesky, scheduled_tile_cholesky
from repro.verify.generators import spd_matrix

NB = 8
POLICIES = {
    "full": PrecisionPolicy.full(),
    "mixed": PrecisionPolicy.tpu(2),
    "three_tier": PrecisionPolicy.three_tier(1, 3),
}
PS = (1, 4, 8)
OOO = SchedConfig(priority="critical_path", workers=4)     # out of order
INORDER = SchedConfig(priority="fifo", workers=1)          # == emission order


def _same_bits(x, y) -> bool:
    """Bitwise equality, NaN == NaN (lo tiers can round to NaN identically)."""
    if x.dtype != y.dtype or x.shape != y.shape:
        return False
    return bool(jnp.all((x == y) | (jnp.isnan(x) & jnp.isnan(y))))


def _assert_stores_equal(got: dict, want: dict, ctx: str) -> None:
    assert set(got) == set(want), ctx
    for tile in sorted(got):
        assert _same_bits(got[tile], want[tile]), f"{ctx}: tile {tile}"


# ---------------------------------------------------------------------------
# tile variant vs core.tile_cholesky
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
def test_tile_scheduled_bitwise(label, p):
    pol = POLICIES[label]
    a = spd_matrix(p, p * NB, cond=100.0)
    l_seq = tile_cholesky(a, NB, pol)                      # eager engine
    l_ooo, rep = scheduled_tile_cholesky(a, NB, pol, OOO)
    assert rep.n_tasks > 0 and _same_bits(l_ooo, l_seq), (label, p)
    # out-of-order == in-order replay of the same DAG, tile by tile
    s_ooo, _ = scheduled_cholesky(a, NB, pol, OOO, variant="tile")
    s_ord, _ = scheduled_cholesky(a, NB, pol, INORDER, variant="tile")
    _assert_stores_equal(s_ooo, s_ord, f"tile/{label}/p={p}")


def test_tile_scheduled_bitwise_across_priorities():
    pol = POLICIES["mixed"]
    a = spd_matrix(17, 4 * NB, cond=100.0)
    l_seq = tile_cholesky(a, NB, pol)
    for priority in ("fifo", "panel_first", "critical_path"):
        cfg = SchedConfig(priority=priority, workers=4)
        l, _ = scheduled_tile_cholesky(a, NB, pol, cfg)
        assert _same_bits(l, l_seq), priority


def test_core_schedule_hook():
    """`tile_cholesky(..., schedule=cfg)` is a drop-in for the loop nest."""
    pol = POLICIES["three_tier"]
    a = spd_matrix(3, 4 * NB, cond=100.0)
    assert _same_bits(tile_cholesky(a, NB, pol, schedule=OOO),
                      tile_cholesky(a, NB, pol))


# ---------------------------------------------------------------------------
# panel variant vs core.panel_cholesky_banded
# ---------------------------------------------------------------------------

def _banded_from_dense(a, nb, pol):
    """Pack a dense SPD matrix into the panel engine's band/off storage."""
    tiles, p = split_tiles(a, nb)
    t = min(pol.diag_thick, p)
    hi = pol.hi
    lo = pol.lo if pol.mode != "full" else pol.hi
    band = jnp.zeros((p, t, nb, nb), hi)
    off = jnp.zeros((p, p, nb, nb), lo)
    for (i, j), x in tiles.items():
        d = i - j
        if d < t:
            band = band.at[i, d].set(x.astype(hi))
        else:
            off = off.at[i, j].set(x.astype(lo))
    return band, off, p, t


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
def test_panel_scheduled_bitwise(label, p):
    pol = POLICIES[label]
    a = spd_matrix(100 + p, p * NB, cond=100.0)
    band, off, _, t = _banded_from_dense(a, NB, pol)
    band_r, off_r = panel_cholesky_banded(band, off, pol, off_update="square")
    store, _ = scheduled_cholesky(a, NB, pol, OOO, variant="panel")
    for (i, j), v in sorted(store.items()):
        d = i - j
        ref = band_r[i, d] if d < t else off_r[i, j]
        assert _same_bits(v, ref), f"panel/{label}/p={p}: tile {(i, j)}"


# ---------------------------------------------------------------------------
# dst variant vs core.dst_cholesky
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
def test_dst_scheduled_vs_dense_blocks(label, p):
    pol = POLICIES[label]
    a = spd_matrix(200 + p, p * NB, cond=100.0)
    s_ooo, _ = scheduled_cholesky(a, NB, pol, OOO, variant="dst")
    s_ord, _ = scheduled_cholesky(a, NB, pol, INORDER, variant="dst")
    _assert_stores_equal(s_ooo, s_ord, f"dst/{label}/p={p}")
    for sl, lb in dst_cholesky(a, NB, pol.diag_thick, hi=pol.hi):
        i0 = sl.start // NB
        width = (sl.stop - sl.start) // NB
        for ii in range(width):
            for jj in range(ii + 1):
                v = s_ooo[(i0 + ii, i0 + jj)]
                ref = lb[..., ii * NB:(ii + 1) * NB, jj * NB:(jj + 1) * NB]
                if width == 1:
                    # single-tile block: same op, must match bitwise
                    assert _same_bits(v, ref), f"dst/{label}/p={p}"
                else:
                    # tile-level right-looking vs one dense LAPACK block:
                    # algorithmically different blocking, numerically tight
                    np.testing.assert_allclose(
                        np.asarray(v, np.float64), np.asarray(ref, np.float64),
                        atol=1e-4 * float(jnp.abs(a).max()))


@pytest.mark.parametrize("p", (1, 4))
def test_dst_full_policy_equals_tile_full(p):
    """full's band covers everything: the dst DAG degenerates to the tile
    DAG's hi path and must match `tile_cholesky` bitwise."""
    pol = POLICIES["full"]
    a = spd_matrix(300 + p, p * NB, cond=100.0)
    store, _ = scheduled_cholesky(a, NB, pol, OOO, variant="dst")
    ref_store, _ = split_tiles(tile_cholesky(a, NB, pol), NB)
    tiles, _ = split_tiles(a, NB)
    for (i, j) in tiles:
        assert _same_bits(store[(i, j)], ref_store[(i, j)]), (i, j)


# ---------------------------------------------------------------------------
# property: bitwise equivalence over random problems and policies
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(sts.spd_problems(sizes=(64,), tiles=(16,)),
           sts.mixed_policies(max_thick=2))
    @settings(max_examples=6, deadline=None)
    def test_property_scheduled_tile_bitwise(problem, pol):
        """Property: for any SPD problem and non-dst policy, the threaded
        out-of-order executor reproduces the sequential engine bitwise."""
        a, nb = problem
        l, _ = scheduled_tile_cholesky(a, nb, pol, OOO)
        assert _same_bits(l, tile_cholesky(a, nb, pol))
else:
    @pytest.mark.skip(reason=HYPOTHESIS_SKIP_REASON)
    def test_property_scheduled_tile_bitwise():
        pass
