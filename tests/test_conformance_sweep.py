"""The conformance sweep as a test suite: bounds, claims, coverage, golden.

One sweep run (module-scoped fixture, ~1 min on CPU) feeds four gates:

  1. every record inside its registered tolerance bound (bounds.py);
  2. the paper's headline claim, asserted directly: mixed precision does
     NOT deteriorate accuracy, while the DST tapering baseline does;
  3. coverage: all four kernel pairs and all three Cholesky variants on
     the full SIZES x REGIMES grid -- a silently skipped variant fails;
  4. the golden regression gate (pass --update-golden to re-baseline).
"""

import numpy as np
import pytest

from repro.verify import (
    GOLDEN_PATH,
    REGIMES,
    SIZES,
    check_records,
    compare_to_golden,
    lookup_bound,
    run_conformance,
    save_golden,
)
from repro.verify.golden import load_golden

pytestmark = pytest.mark.accuracy


@pytest.fixture(scope="module")
def records():
    return run_conformance()


def _by_id(records):
    return {r["id"]: r for r in records}


def test_all_records_within_registered_bounds(records):
    violations = check_records(records)
    assert violations == [], "\n".join(f"{rid}: {msg}"
                                       for rid, msg in violations)


def test_no_deterioration_claim(records):
    """The paper's central claim, on the production pair: the mixed factor
    tracks the fp64 oracle at low-precision rounding scale, and the DST
    baseline at the same band width is a magnitude worse."""
    recs = _by_id(records)
    for n in SIZES:
        for regime in REGIMES:
            mixed = recs[f"chol/tile/mixed_f32bf16_t2/n{n}_{regime}"]
            dst = recs[f"chol/dst/t2/n{n}_{regime}"]
            bound = lookup_bound("mixed", "f32/bf16", 2, regime)
            assert mixed["factor_rel"] <= bound.factor_rel
            assert mixed["loglik_drift"] <= bound.loglik_drift
            if n >= 128:  # at n=64, p=2 the DST super-tile covers most of A
                assert dst["factor_rel"] > 10 * mixed["factor_rel"], (
                    f"n{n}_{regime}: DST should deteriorate, mixed should "
                    f"not -- dst={dst['factor_rel']:.2e} "
                    f"mixed={mixed['factor_rel']:.2e}")


def test_paper_pair_matches_f64_reference(records):
    """fp64 band / fp32 off-band: 'no deterioration' at the paper's own
    dtype pair -- factor error stays at fp32 rounding scale."""
    for rec in records:
        if rec["id"].startswith("chol/tile/paper_f64f32_t2/"):
            assert rec["factor_rel"] < 1e-5
            assert rec["loglik_drift"] < 1e-6


def test_sweep_coverage(records):
    recs = _by_id(records)
    # three Cholesky variants on the full grid
    for n in SIZES:
        for regime in REGIMES:
            for variant in (f"chol/tile/full_f32/n{n}_{regime}",
                            f"chol/tile/mixed_f32bf16_t2/n{n}_{regime}",
                            f"chol/panel/mixed_f32bf16_t2/n{n}_{regime}",
                            f"chol/dst/t2/n{n}_{regime}",
                            f"krige/mixed_f32bf16_t2/n{n}_{regime}"):
                assert variant in recs, f"sweep lost coverage of {variant}"
    # all four kernel pairs, >= 9 cases each (3 shapes x 3 regimes)
    kernels = {}
    for rec in records:
        if rec["kind"] == "kernel":
            kernels[rec["kernel"]] = kernels.get(rec["kernel"], 0) + 1
    assert set(kernels) == {"matern_cov", "mp_syrk", "blocked_potrf",
                            "mp_attention"}
    assert all(count >= 9 for count in kernels.values()), kernels


def test_mixed_beats_dst_on_likelihood(records):
    """Accuracy ordering the paper's Fig. 7/8 relies on, in aggregate."""
    drift = lambda pat: np.median([r["loglik_drift"] for r in records
                                   if r["id"].startswith(pat)])
    assert drift("chol/tile/mixed_f32bf16_t2/") < drift("chol/dst/")


def test_golden_regression_gate(records, request):
    if request.config.getoption("--update-golden"):
        path = save_golden(records)
        pytest.skip(f"rewrote golden baseline at {path}")
    assert GOLDEN_PATH.exists(), (
        "no golden baseline committed -- run "
        "pytest tests/test_conformance_sweep.py --update-golden")
    drifts = compare_to_golden(records, load_golden())
    assert drifts == [], "\n".join(f"{rid}: {msg}" for rid, msg in drifts)
