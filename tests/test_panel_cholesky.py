"""TPU-native panel engine: equivalence with the faithful tile engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PrecisionPolicy,
    assemble_from_banded,
    banded_forward_solve,
    banded_loglik,
    build_banded_covariance,
    geostat_loglik_step,
    loglik_from_factor,
    panel_cholesky_banded,
    reference_cholesky,
    tile_cholesky,
)

NB = 32
T = 2


@pytest.fixture(scope="module")
def banded(small_dataset):
    pol = PrecisionPolicy.tpu(diag_thick=T)
    band, off = build_banded_covariance(
        small_dataset.locs, small_dataset.theta0, nb=NB, policy=pol,
        nu_static=0.5, jitter=1e-5)
    return band, off, pol


def test_banded_matches_tile_engine(small_dataset, small_cov, banded):
    band, off, pol = banded
    band_f, off_f = panel_cholesky_banded(band, off, pol)
    l_panel = assemble_from_banded(band_f, off_f, T)
    l_tile = tile_cholesky(small_cov, NB, pol)
    np.testing.assert_allclose(np.asarray(l_panel), np.asarray(l_tile),
                               rtol=1e-3, atol=1e-4)


def test_chunked_equals_square(banded):
    band, off, pol = banded
    b1, o1 = panel_cholesky_banded(band, off, pol, off_update="square")
    b2, o2 = panel_cholesky_banded(band, off, pol, off_update="chunked")
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=1e-2, atol=1e-3)


def test_full_policy_panel_equals_lapack(small_dataset, small_cov):
    pol = PrecisionPolicy.full(jnp.float32)
    band, off = build_banded_covariance(
        small_dataset.locs, small_dataset.theta0, nb=NB, policy=pol,
        nu_static=0.5, jitter=1e-5)
    band_f, off_f = panel_cholesky_banded(band, off, pol)
    t_eff = min(pol.diag_thick, band.shape[0])
    l_panel = assemble_from_banded(band_f, off_f, t_eff)
    l_ref = reference_cholesky(small_cov, jnp.float32)
    np.testing.assert_allclose(np.asarray(l_panel), np.asarray(l_ref),
                               rtol=1e-3, atol=1e-4)


def test_banded_solve_and_loglik(small_dataset, banded):
    band, off, pol = banded
    band_f, off_f = panel_cholesky_banded(band, off, pol)
    l_panel = assemble_from_banded(band_f, off_f, T)
    z = small_dataset.z
    w_banded = banded_forward_solve(band_f, off_f, z, T)
    w_dense = jax.scipy.linalg.solve_triangular(l_panel, z.astype(l_panel.dtype),
                                                lower=True)
    np.testing.assert_allclose(np.asarray(w_banded), np.asarray(w_dense),
                               rtol=1e-3, atol=1e-3)
    ll_banded = float(banded_loglik(band_f, off_f, z, T))
    ll_dense = float(loglik_from_factor(l_panel, z))
    assert ll_banded == pytest.approx(ll_dense, rel=1e-4)


def test_geostat_loglik_step_jits_and_matches(small_dataset):
    pol = PrecisionPolicy.tpu(diag_thick=T)
    f = jax.jit(lambda th: geostat_loglik_step(
        small_dataset.locs, small_dataset.z, th, nb=NB, policy=pol,
        nu_static=0.5))
    ll = float(f(small_dataset.theta0))
    l_ref = reference_cholesky(
        jnp.asarray(np.asarray(
            __import__("repro.core", fromlist=["build_covariance"])
            .build_covariance(small_dataset.locs, small_dataset.theta0,
                              nu_static=0.5, jitter=1e-6))), jnp.float32)
    ll_ref = float(loglik_from_factor(l_ref, small_dataset.z))
    assert ll == pytest.approx(ll_ref, abs=2.0)  # bf16 off-band likelihood shift


def test_gradient_flows_through_panel_engine(small_dataset):
    pol = PrecisionPolicy.tpu(diag_thick=T)

    def nll(log_range):
        theta = jnp.array([1.0, jnp.exp(log_range), 0.5])
        return -geostat_loglik_step(small_dataset.locs, small_dataset.z, theta,
                                    nb=NB, policy=pol, nu_static=0.5)

    g = jax.grad(nll)(jnp.float32(np.log(0.1)))
    assert np.isfinite(float(g))
