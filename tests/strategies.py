"""Shared hypothesis strategies for the property-based tests.

This module imports hypothesis unconditionally -- test modules must guard
with `conftest.HAVE_HYPOTHESIS` before importing it, so the deterministic
tests in the same files keep running without the optional extra.

Problem matrices come from `repro.verify.generators`, the same distribution
the conformance sweep and benchmarks draw from: a property that fails here
points at a problem the accuracy gates would also see.
"""

import jax.numpy as jnp
from hypothesis import strategies as st

from repro.core import PrecisionPolicy
from repro.verify.generators import spd_matrix

seeds = st.integers(0, 2**31 - 1)

# Bessel / Matern parameter ranges exercised by the covariance properties
matern_nus = st.floats(0.05, 4.5)
bessel_args = st.floats(1e-3, 50.0)


@st.composite
def tile_geometries(draw, sizes=(64, 128), tiles=(16, 32)):
    """(n, nb) with nb | n, so the tile grid is exact."""
    n = draw(st.sampled_from(sizes))
    nb = draw(st.sampled_from(tiles))
    return n, nb


@st.composite
def spd_problems(draw, sizes=(64, 128), tiles=(16, 32),
                 conds=(10.0, 100.0, 1e4)):
    """(spd matrix, nb): controlled-condition SPD problem + tile size."""
    n, nb = draw(tile_geometries(sizes, tiles))
    a = spd_matrix(draw(seeds), n, cond=draw(st.sampled_from(conds)))
    return a, nb


@st.composite
def precision_policies(draw, max_thick=4):
    """Any valid policy: full, the mixed pairs, dst, or three-tier."""
    mode = draw(st.sampled_from(["full", "mixed_tpu", "mixed_paper", "dst",
                                 "three_tier"]))
    t = draw(st.integers(1, max_thick))
    if mode == "full":
        return PrecisionPolicy.full(jnp.float32)
    if mode == "mixed_tpu":
        return PrecisionPolicy.tpu(diag_thick=t)
    if mode == "mixed_paper":
        return PrecisionPolicy.paper_cpu(diag_thick=t)
    if mode == "dst":
        return PrecisionPolicy.dst(t)
    return PrecisionPolicy.three_tier(t, t + draw(st.integers(1, 2)))


@st.composite
def mixed_policies(draw, max_thick=4):
    """Policies whose factor approximates the dense one (no dst zeroing)."""
    pol = draw(precision_policies(max_thick))
    if pol.mode == "dst" or pol.hi == jnp.float64:
        return PrecisionPolicy.tpu(diag_thick=pol.diag_thick)
    return pol
