"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.covariance import random_locations
from repro.kernels.matern_cov.ops import matern_cov
from repro.kernels.matern_cov.ref import matern_cov_ref
from repro.kernels.mp_gemm.ops import mp_syrk
from repro.kernels.mp_gemm.ref import mp_syrk_ref
from repro.kernels.blocked_potrf.ops import potrf
from repro.kernels.blocked_potrf.ref import potrf_ref
from repro.kernels.mp_attention.ops import banded_decode_attention, quantize_kv
from repro.kernels.mp_attention.ref import banded_decode_attention_ref
from conftest import spd_matrix


# ----------------------------- matern_cov -----------------------------

@pytest.mark.parametrize("nu", [0.5, 1.5, 2.5])
@pytest.mark.parametrize("m,n,bm,bn", [(128, 128, 64, 64), (256, 128, 128, 128),
                                       (64, 192, 32, 64)])
def test_matern_cov_kernel_matches_ref(nu, m, n, bm, bn):
    key = jax.random.PRNGKey(0)
    la = random_locations(key, m)
    lb = random_locations(jax.random.PRNGKey(1), n)
    theta = jnp.array([1.3, 0.12, nu])
    out = matern_cov(la, lb, theta, nu=nu, bm=bm, bn=bn)
    ref = matern_cov_ref(la, lb, theta, nu=nu)
    # kernel uses the MXU-friendly |x|^2+|y|^2-2xy distance: fp32
    # cancellation for near-coincident points costs ~1e-4 relative
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matern_cov_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(2)
    la = random_locations(key, 128)
    theta = jnp.array([1.0, 0.1, 0.5])
    out = matern_cov(la, la, theta, nu=0.5, bm=64, bn=64, out_dtype=dtype)
    assert out.dtype == dtype
    ref = matern_cov_ref(la, la, theta, nu=0.5, out_dtype=dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-2, atol=1e-2)


def test_matern_cov_general_nu_fallback():
    la = random_locations(jax.random.PRNGKey(3), 128)
    theta = jnp.array([1.0, 0.1, 1.27])
    out = matern_cov(la, la, theta, nu=1.27)
    ref = matern_cov_ref(la, la, theta, nu=1.27)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ------------------------------ mp_gemm -------------------------------

@pytest.mark.parametrize("m,k,bm,bk,band", [
    (256, 128, 64, 64, 1), (256, 128, 64, 64, 2), (128, 256, 64, 128, 1),
    (256, 64, 128, 64, 4),  # band >= nblocks: all-hi
])
def test_mp_syrk_matches_ref(m, k, bm, bk, band):
    p = jax.random.normal(jax.random.PRNGKey(4), (m, k), jnp.float32)
    out = mp_syrk(p, band_blocks=band, bm=bm, bk=bk)
    ref = mp_syrk_ref(p, band_blocks=band, bm=bm, bk=bk)
    # sub-bf16-ulp accumulation-order noise between interpret-mode dot and
    # the jnp reference is expected on off-band blocks
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_mp_syrk_band_is_exact_offband_is_bf16():
    m, k, bm = 256, 128, 64
    p = jax.random.normal(jax.random.PRNGKey(5), (m, k), jnp.float32)
    out = np.asarray(mp_syrk(p, band_blocks=1, bm=bm, bk=k))
    exact = np.asarray(p) @ np.asarray(p).T
    # diagonal blocks exact to fp32
    for i in range(m // bm):
        sl = slice(i * bm, (i + 1) * bm)
        np.testing.assert_allclose(out[sl, sl], exact[sl, sl], rtol=1e-5)
    # off-diagonal blocks carry bf16 rounding (error ~1e-2 relative)
    off_err = np.abs(out[:bm, bm:2 * bm] - exact[:bm, bm:2 * bm]).max()
    assert 1e-5 < off_err / np.abs(exact).max() < 0.05


# ---------------------------- blocked_potrf ---------------------------

@pytest.mark.parametrize("n", [32, 64, 128, 256])
def test_potrf_matches_lapack(n):
    a = spd_matrix(jax.random.PRNGKey(6), n, cond=100.0)
    out = potrf(a)
    ref = potrf_ref(a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_potrf_batched():
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    a = jnp.stack([spd_matrix(k, 64) for k in keys])
    out = potrf(a)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(potrf_ref(a[i])),
                                   rtol=5e-4, atol=5e-4)


# ---------------------------- mp_attention ----------------------------

def _mk_attn(key, b, g, d, sn, sf, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, g, d), dtype)
    kn = jax.random.normal(ks[1], (b, sn, d), dtype)
    vn = jax.random.normal(ks[2], (b, sn, d), dtype)
    kf = jax.random.normal(ks[3], (b, sf, d), dtype)
    vf = jax.random.normal(ks[4], (b, sf, d), dtype)
    return q, kn, vn, kf, vf


@pytest.mark.parametrize("b,g,d,sn,sf,blk", [
    (2, 4, 64, 128, 256, 128), (1, 8, 128, 256, 128, 64), (4, 1, 64, 128, 128, 128),
])
def test_banded_attention_matches_ref(b, g, d, sn, sf, blk):
    q, kn, vn, kf, vf = _mk_attn(jax.random.PRNGKey(8), b, g, d, sn, sf)
    kq, vq, scales = quantize_kv(kf, vf, blk=blk)
    near_len = jnp.full((b,), sn, jnp.int32)
    far_len = jnp.full((b,), sf, jnp.int32)
    sm = 1.0 / np.sqrt(d)
    out = banded_decode_attention(q, kn, vn, near_len, kq, vq, scales, far_len,
                                  blk=blk, sm_scale=sm)
    ref = banded_decode_attention_ref(q, kn, vn, near_len, kq, vq, scales,
                                      far_len, blk=blk, sm_scale=sm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_banded_attention_ragged_lengths():
    b, g, d, sn, sf, blk = 2, 4, 64, 128, 256, 128
    q, kn, vn, kf, vf = _mk_attn(jax.random.PRNGKey(9), b, g, d, sn, sf)
    kq, vq, scales = quantize_kv(kf, vf, blk=blk)
    near_len = jnp.array([128, 70], jnp.int32)
    far_len = jnp.array([200, 0], jnp.int32)
    sm = 1.0 / np.sqrt(d)
    out = banded_decode_attention(q, kn, vn, near_len, kq, vq, scales, far_len,
                                  blk=blk, sm_scale=sm)
    ref = banded_decode_attention_ref(q, kn, vn, near_len, kq, vq, scales,
                                      far_len, blk=blk, sm_scale=sm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_quantization_error_is_small_but_nonzero():
    """int8 far cache: ~1% attention output error -- the accuracy/bytes
    trade the paper makes, at KV-cache scale."""
    b, g, d, sn, sf = 2, 4, 64, 128, 256
    q, kn, vn, kf, vf = _mk_attn(jax.random.PRNGKey(10), b, g, d, sn, sf)
    kq, vq, scales = quantize_kv(kf, vf)
    near_len = jnp.full((b,), sn, jnp.int32)
    far_len = jnp.full((b,), sf, jnp.int32)
    sm = 1.0 / np.sqrt(d)
    out = banded_decode_attention(q, kn, vn, near_len, kq, vq, scales, far_len,
                                  sm_scale=sm)
    # exact attention with the unquantized far segment
    k_all = jnp.concatenate([kn, kf], axis=1)
    v_all = jnp.concatenate([vn, vf], axis=1)
    scores = jnp.einsum("bgd,bsd->bgs", q, k_all) * sm
    p = jax.nn.softmax(scores, axis=-1)
    exact = jnp.einsum("bgs,bsd->bgd", p, v_all)
    err = float(jnp.max(jnp.abs(out - exact)))
    assert 1e-6 < err < 0.05
