"""MLE parameter recovery + kriging prediction (paper Sec. VIII-D, scaled)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PrecisionPolicy,
    fit_mle,
    fit_mle_adam,
    kfold_pmse,
    krige,
    make_loglik,
    pmse,
)
from repro.covariance import make_dataset

NB = 32


def _fit(ds, policy, max_iters=60):
    ll = make_loglik(ds.locs, ds.z, policy, nb=NB, nu_static=0.5)
    f = lambda th2: ll(jnp.concatenate([th2, jnp.array([0.5])]))
    return fit_mle(f, [0.8, 0.08], max_iters=max_iters)


@pytest.fixture(scope="module")
def med_ds():
    return make_dataset(jax.random.PRNGKey(3), 256, [1.0, 0.1, 0.5], nu_static=0.5)


@pytest.fixture(scope="module")
def dp_fit(med_ds):
    """Full-precision NM fit, shared by the recovery and gradient tests."""
    return _fit(med_ds, PrecisionPolicy.full(jnp.float32))


def test_dp_recovers_parameters(dp_fit):
    # tolerances reflect sampling variability of the range MLE at n=256:
    # this realization's true optimum is range-hat ~ 0.17 (both the NM and
    # Adam drivers agree); the paper averages 100 reps at n=40k
    assert dp_fit.theta[0] == pytest.approx(1.0, abs=0.5)
    # two-sided band (not approx(0.1, abs=0.1), which would accept 0): the
    # estimate must stay the right order of magnitude around the truth
    assert 0.05 < dp_fit.theta[1] < 0.2


def test_mp_estimates_close_to_dp(med_ds):
    """The paper's central accuracy claim at test scale."""
    res_dp = _fit(med_ds, PrecisionPolicy.full(jnp.float32))
    res_mp = _fit(med_ds, PrecisionPolicy.tpu(diag_thick=2))
    np.testing.assert_allclose(res_mp.theta, res_dp.theta, rtol=0.25)


def test_profiled_likelihood_consistent(med_ds):
    """Eq. 3 profiled MLE finds the same range parameter as Eq. 2."""
    pol = PrecisionPolicy.full(jnp.float32)
    ll3 = make_loglik(med_ds.locs, med_ds.z, pol, nb=NB, nu_static=0.5,
                      profiled=True)
    res3 = fit_mle(lambda th: ll3(jnp.array([th[0], 0.5])), [0.08], max_iters=50)
    res2 = _fit(med_ds, pol)
    assert res3.theta[0] == pytest.approx(res2.theta[1], rel=0.15)


def test_adam_gradient_path(med_ds, dp_fit):
    pol = PrecisionPolicy.full(jnp.float32)
    ll = make_loglik(med_ds.locs, med_ds.z, pol, nb=NB, nu_static=0.5)
    res = fit_mle_adam(lambda th: ll(jnp.concatenate([th, jnp.array([0.5])])),
                       [0.8, 0.08], steps=120, lr=0.05)
    # same sampling-variability band as test_dp_recovers_parameters, and the
    # gradient path must land on the same optimum as the (shared) NM fit
    assert 0.05 < res.theta[1] < 0.2
    assert res.theta[1] == pytest.approx(dp_fit.theta[1], rel=0.1)


def test_krige_interpolates_at_observed_points(med_ds):
    pol = PrecisionPolicy.full(jnp.float32)
    obs = slice(0, 224)
    mu = krige(med_ds.locs[obs], med_ds.z[obs], med_ds.locs[:16],
               med_ds.theta0, pol, nb=NB, nu_static=0.5, jitter=1e-6)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(med_ds.z[:16]),
                               rtol=0.05, atol=0.02)


def test_krige_variance_positive_and_zero_at_obs(med_ds):
    pol = PrecisionPolicy.full(jnp.float32)
    mu, var = krige(med_ds.locs[:224], med_ds.z[:224], med_ds.locs[224:],
                    med_ds.theta0, pol, nb=NB, nu_static=0.5, return_var=True)
    v = np.asarray(var)
    assert np.all(v > -1e-4)
    assert np.all(v < 1.0 + 1e-4)  # bounded by the prior variance theta1


def test_mp_pmse_close_to_dp(med_ds):
    """Paper Fig. 8: mixed-precision PMSE ~ DP PMSE."""
    dp, _ = kfold_pmse(med_ds.locs, med_ds.z, med_ds.theta0,
                       PrecisionPolicy.full(jnp.float32), k=4, nb=NB,
                       nu_static=0.5)
    mp, _ = kfold_pmse(med_ds.locs, med_ds.z, med_ds.theta0,
                       PrecisionPolicy.tpu(diag_thick=2), k=4, nb=NB,
                       nu_static=0.5)
    assert mp == pytest.approx(dp, rel=0.2)


def test_dst_pmse_worse_than_mp_on_medium_correlation(med_ds):
    """Paper's key comparison: tapering-to-zero loses accuracy that
    tapering-to-lower-precision keeps (medium correlation)."""
    mp, _ = kfold_pmse(med_ds.locs, med_ds.z, med_ds.theta0,
                       PrecisionPolicy.tpu(diag_thick=1), k=4, nb=NB,
                       nu_static=0.5)
    # DST with the same band width (predicting through a block-diagonal
    # covariance: correlations to most observations are destroyed)
    from repro.core import build_covariance, dst_cholesky, dst_loglik
    # kriging under DST == kriging per independent block
    import numpy as onp
    n = med_ds.locs.shape[0]
    rng = onp.random.default_rng(0)
    perm = rng.permutation(n)
    test_idx = perm[:32]
    train_mask = onp.ones(n, bool); train_mask[test_idx] = False
    tr = onp.nonzero(train_mask)[0][:192]
    # DST prediction: use only the super-block containing each target -> here
    # approximate by kriging with block-diagonal cov: zero cross-cov outside
    # block means prediction from a small neighbourhood subset.
    pol = PrecisionPolicy.full(jnp.float32)
    mu_blocks = []
    super_nb = 1 * NB
    for s in range(0, len(tr), super_nb):
        idx = tr[s:s + super_nb]
        mu_b = krige(med_ds.locs[idx], med_ds.z[idx], med_ds.locs[test_idx],
                     med_ds.theta0, pol, nb=NB, nu_static=0.5)
        mu_blocks.append(np.asarray(mu_b))
    # DST predictor: average of per-block predictions is NOT the DST one;
    # instead use nearest block (max |cross-cov|) -- simplified: first block
    # prediction error must exceed full-kriging error.
    dst_err = float(pmse(jnp.asarray(mu_blocks[0]), med_ds.z[test_idx]))
    assert dst_err > mp * 1.2
