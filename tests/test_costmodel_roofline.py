"""Validate the analytic roofline cost model + HLO collective parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import ShapeSpec
from repro.core.precision import PrecisionPolicy
from repro.launch.costmodel import (_forward_flops, geostat_cell_cost,
                                    geostat_dag_cost, lm_cell_cost)
from repro.launch.roofline import collective_bytes_from_hlo
from repro.models.config import ArchConfig, MoESpec
from repro.models.transformer import forward_lm, init_lm


def _hlo_flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost["flops"])


def test_analytic_flops_matches_xla_dense():
    """Single-cycle model (scan trip count 1) => cost_analysis is exact;
    the analytic model must agree within 25%."""
    cfg = ArchConfig(name="v", family="dense", n_layers=1, d_model=128,
                     n_heads=8, n_kv_heads=4, d_head=16, d_ff=512,
                     vocab=1024, remat=False)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((4, 256), jnp.int32)
    measured = _hlo_flops(lambda p, t: forward_lm(p, t, cfg,
                                                  compute_dtype=jnp.float32)[0],
                          params, toks)
    analytic = _forward_flops(cfg, 4, 256)
    assert measured == pytest.approx(analytic, rel=0.25), \
        (measured, analytic)


def test_analytic_flops_matches_xla_moe():
    cfg = ArchConfig(name="vm", family="moe", n_layers=1, d_model=128,
                     n_heads=8, n_kv_heads=4, d_head=16, d_ff=0, vocab=1024,
                     moe=MoESpec(n_experts=8, top_k=2, d_expert=256),
                     remat=False)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((4, 256), jnp.int32)
    measured = _hlo_flops(lambda p, t: forward_lm(p, t, cfg,
                                                  compute_dtype=jnp.float32)[0],
                          params, toks)
    analytic = _forward_flops(cfg, 4, 256)
    assert measured == pytest.approx(analytic, rel=0.3), (measured, analytic)


def test_lm_cell_cost_scaling_laws():
    """Sanity relations the roofline table relies on."""
    cfg = ArchConfig(name="s", family="dense", n_layers=4, d_model=256,
                     n_heads=8, n_kv_heads=4, d_head=32, d_ff=1024,
                     vocab=4096)
    axes = {"data": 16, "model": 16}
    train = ShapeSpec("t", "train", 4096, 256)
    decode = ShapeSpec("d", "decode", 32768, 128)
    c_train = lm_cell_cost(cfg, train, chips=256, mesh_axes=axes)
    c_dec = lm_cell_cost(cfg, decode, chips=256, mesh_axes=axes)
    assert c_train.flops > 100 * c_dec.flops          # train >> decode flops
    assert c_dec.hbm_bytes < c_train.hbm_bytes
    # kv_quant halves (approximately) the decode cache bytes
    c_dec_q = lm_cell_cost(cfg, decode, chips=256, mesh_axes=axes,
                           opts={"kv_quant": True})
    cache = c_dec.detail["cache_bytes"]
    cache_q = c_dec_q.detail["cache_bytes"]
    assert 0.4 < cache_q / cache < 0.6
    # no_fsdp removes the gather term
    c_nf = lm_cell_cost(cfg, train, chips=256, mesh_axes=axes,
                        opts={"no_fsdp": True})
    assert c_nf.collective_bytes_per_chip < c_train.collective_bytes_per_chip


def test_geostat_cost_band_fraction():
    c_mp = geostat_cell_cost(65536, 2048, diag_thick=4, chips=256)
    c_dp = geostat_cell_cost(65536, 2048, diag_thick=32, chips=256)
    assert c_dp.flops > c_mp.flops            # all-fp32 band costs more
    assert 0 < c_mp.detail["band_frac"] < 0.5
    # aligned version cuts the masked-full waste
    c_al = geostat_cell_cost(65536, 2048, diag_thick=4, chips=256,
                             off_update="aligned")
    assert c_al.flops < c_mp.flops


def test_geostat_dag_cost_exact_counts():
    # the DAG-fed sibling of geostat_cell_cost: raw task totals are exactly
    # p^3/3 * nb^3, and widening the fp32 band raises the weighted cost
    c2 = geostat_dag_cost(4096, 512, PrecisionPolicy.tpu(2), chips=16)
    c4 = geostat_dag_cost(4096, 512, PrecisionPolicy.tpu(4), chips=16)
    p, nb = 8, 512
    assert c2.detail["total_flops"] == pytest.approx((p**3 / 3) * nb**3)
    assert c2.model_flops == pytest.approx(4096**3 / 3)
    assert c4.flops > c2.flops                # more x6-weighted hi tiles
    assert c4.detail["hi_frac"] > c2.detail["hi_frac"]
    assert c2.detail["critical_path_tasks"] == 3 * p - 2
    # full policy degenerates to all-hi, conversion-free
    c_full = geostat_dag_cost(4096, 512, PrecisionPolicy.full(), chips=16)
    assert c_full.detail["hi_frac"] == pytest.approx(1.0)
    assert c_full.detail["convert_tiles"] == 0


def test_collective_parser_on_real_hlo():
    """K-sharded matmul must produce one all-reduce of known size."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32,
                             sharding=NamedSharding(mesh, P("model", None)))

    def f(a, b):
        return jax.lax.with_sharding_constraint(
            a @ b, NamedSharding(mesh, P()))

    compiled = jax.jit(f).lower(a, b).compile()
    coll = collective_bytes_from_hlo(compiled.as_text())
    # on 1 device XLA may elide the all-reduce; the parser must not crash
    assert coll["total"] >= 0
    assert set(coll) >= {"all-reduce", "all-gather", "total", "count"}


def test_collective_parser_synthetic_hlo():
    hlo = """
  %ar = f32[512,1024]{1,0} all-reduce(%dot), channel_id=1
  %ag.1 = bf16[64,256]{1,0} all-gather(%x), dimensions={0}
  %ars = f32[16]{0} all-reduce-start(%y)
  %ard = f32[16]{0} all-reduce-done(%ars)
  %cp = s8[128]{0} collective-permute(%z)
  %unrelated = f32[9999]{0} add(%a, %b)
"""
    coll = collective_bytes_from_hlo(hlo)
    assert coll["all-reduce"] == 512 * 1024 * 4 + 16 * 4
    assert coll["all-gather"] == 64 * 256 * 2
    assert coll["collective-permute"] == 128
    assert coll["count"] == 4
