"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement).
The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, SMOKE_ARCHS
from repro.data import DataConfig, SyntheticTokenSource
from repro.train import TrainConfig, init_train_state, make_train_step

ARCH_NAMES = sorted(SMOKE_ARCHS.keys())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = SMOKE_ARCHS[name]
    tc = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=10)
    state, axes = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    src = SyntheticTokenSource(cfg, DataConfig(seed=0, global_batch=2,
                                               seq_len=16))
    batch = src.batch_at(0)
    step = jax.jit(make_train_step(cfg, tc))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, f"{name}: loss={loss}"
    assert np.isfinite(float(metrics["grad_norm"]))
    # params updated and still finite
    leaves = jax.tree.leaves(state["params"])
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    from repro.models.decode import decode_step, init_cache
    from repro.models.transformer import init_lm
    cfg = SMOKE_ARCHS[name]
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, batch=2, max_len=8)
    if cfg.enc_dec:  # cross memory normally filled by prefill
        cache["cross"] = jax.tree.map(
            lambda x: jax.random.normal(jax.random.PRNGKey(1), x.shape,
                                        jnp.float32).astype(x.dtype),
            cache["cross"])
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = decode_step(params, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), name
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_parameters_match_assignment(name):
    """The FULL (non-smoke) configs carry the exact assigned dimensions."""
    cfg = ALL_ARCHS[name]
    expected = {
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 131072),
        "whisper-tiny": (4, 384, 6, 6, 51865),
        "qwen3-4b": (36, 2560, 32, 8, 151936),
        "llama3.2-1b": (16, 2048, 32, 8, 128256),
        "qwen3-32b": (64, 5120, 64, 8, 151936),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 32000),
        "xlstm-1.3b": (48, 2048, 4, 4, 50304),
        "llava-next-34b": (60, 7168, 56, 8, 64000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
    }[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == expected
    if name == "qwen3-moe-30b-a3b":
        assert (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.d_expert) == (128, 8, 768)
    if name == "grok-1-314b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (8, 2)
    if name == "jamba-v0.1-52b":
        assert cfg.block_pattern.count("attn") * 7 == \
            cfg.block_pattern.count("mamba") * 1  # 1:7 interleave
        assert (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.every) == (16, 2, 2)
    if name == "h2o-danube-1.8b":
        assert cfg.swa_window == 4096
    if name == "whisper-tiny":
        assert cfg.enc_dec and cfg.n_enc_layers == 4
    if name == "llava-next-34b":
        assert cfg.frontend == "vision_stub" and cfg.n_patches > 0
    if name == "xlstm-1.3b":
        assert set(cfg.block_pattern) == {"mlstm", "slstm"}
