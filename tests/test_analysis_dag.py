"""Fixture tests for the tile-DAG hazard checker (repro.analysis.dag).

Two directions of evidence:

  * soundness of the engines -- every (variant, policy, p) cell of the
    conformance matrix builds a hazard-free, precision-consistent DAG whose
    totals match the closed-form tile-Cholesky counts (p^3/3 nb^3 units,
    critical path 3p-2 tasks);

  * power of the checker -- corrupted task streams (reordered factor,
    duplicate update, dropped promote, skipped update, write-after-factor,
    no-op convert) each raise HazardError.  Without these, a checker that
    accepts everything would pass the matrix trivially.
"""

import pytest

from repro.analysis.dag import (
    HI,
    LO,
    LO2,
    HazardError,
    Task,
    analyze,
    build_dag,
    check_dag,
    flop_report,
    storage_tier,
)
from repro.core.precision import PrecisionPolicy

POLICIES = {
    "full": PrecisionPolicy.full(),
    "mixed": PrecisionPolicy.tpu(2),
    "three_tier": PrecisionPolicy.three_tier(1, 3),
}
VARIANTS = ("tile", "panel", "dst")
PS = (1, 4, 8)


def _dst_block_sizes(p, diag_thick):
    bs, out, start = min(diag_thick, p), [], 0
    while start < p:
        out.append(min(bs, p - start))
        start += bs
    return out


# ---- the conformance matrix ----------------------------------------------

@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
@pytest.mark.parametrize("variant", VARIANTS)
def test_matrix_cell_hazard_free(variant, label, p):
    rep = analyze(variant, p, POLICIES[label], label=label)
    assert rep.n_tasks >= 1
    fr = rep.tier_fractions()
    assert abs(sum(fr.values()) - 1.0) < 1e-12
    assert rep.critical_path_flops <= rep.total_flops + 1e-12
    assert rep.critical_path_tasks <= rep.n_tasks


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
@pytest.mark.parametrize("variant", ("tile", "panel"))
def test_contiguous_variants_hit_closed_form_totals(variant, label, p):
    # every (i, j, k) update triple is emitted exactly once regardless of
    # tier routing: POTRF p/3 + TRSM p(p-1)/2 + SYRK p(p-1)/2
    # + GEMM p(p-1)(p-2)/3 = p^3/3 nb^3 units, and the longest dependency
    # chain is POTRF -> TRSM -> SYRK repeated down the diagonal: 3p - 2
    rep = analyze(variant, p, POLICIES[label], label=label)
    assert rep.total_flops == pytest.approx(p**3 / 3)
    assert rep.critical_path_tasks == 3 * p - 2


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
def test_dst_totals_are_per_block_dense_cholesky(label, p):
    pol = POLICIES[label]
    rep = analyze("dst", p, pol, label=label)
    blocks = _dst_block_sizes(p, pol.diag_thick)
    assert rep.total_flops == pytest.approx(sum(b**3 / 3 for b in blocks))
    # blocks are independent: critical path is the largest block's chain
    assert rep.critical_path_tasks == 3 * max(blocks) - 2
    assert rep.tier_flops.get(LO, 0.0) == 0.0  # DST math is all hi


def test_full_policy_emits_no_conversions():
    for variant in VARIANTS:
        rep = analyze(variant, 8, POLICIES["full"])
        assert rep.n_converts == 0
        assert rep.tier_fractions() == {HI: 1.0}


def test_mixed_policy_conversion_traffic_matches_paper_ops():
    # tile engine under hi/lo: dlag2s demotes (hi->lo), sconv2d promotes
    # (lo->hi); both directions must appear, and only those two tiers
    rep = analyze("tile", 8, POLICIES["mixed"])
    assert rep.n_converts > 0
    assert f"{HI}->{LO}" in rep.convert_tiles
    assert f"{LO}->{HI}" in rep.convert_tiles
    assert set(rep.tier_flops) == {HI, LO}


def test_three_tier_promotes_lo2_through_lo():
    rep = analyze("tile", 8, POLICIES["three_tier"])
    assert f"{LO2}->{LO}" in rep.convert_tiles   # far TRSM/GEMM operands
    assert rep.tier_flops.get(LO2, 0.0) == 0.0   # fp8 is storage-only


def test_hi_fraction_grows_with_band_width():
    fracs = [analyze("tile", 8, PrecisionPolicy.tpu(t)).tier_fractions()[HI]
             for t in (1, 2, 4, 8)]
    assert fracs == sorted(fracs)
    assert fracs[-1] == pytest.approx(1.0)       # band covers everything


# ---- storage-tier map -----------------------------------------------------

def test_storage_tier_dst_blocks():
    pol = PrecisionPolicy.tpu(2)
    assert storage_tier(pol, 1, 0, variant="dst") == HI     # same 2-block
    assert storage_tier(pol, 2, 1, variant="dst") is None   # crosses blocks


def test_storage_tier_panel_is_two_level_even_for_three_tier():
    pol = POLICIES["three_tier"]
    assert storage_tier(pol, 7, 0, variant="tile") == LO2
    assert storage_tier(pol, 7, 0, variant="panel") == LO   # split storage


# ---- checker power: corrupted streams must be rejected --------------------

def _tile_mixed(p=4):
    return build_dag("tile", p, POLICIES["mixed"]), POLICIES["mixed"]


def _idx(tasks, kind, **attrs):
    for i, t in enumerate(tasks):
        if t.kind == kind and all(getattr(t, k) == v for k, v in attrs.items()):
            return i
    raise AssertionError(f"no {kind} {attrs} in stream")


def _expect_hazard(tasks, policy, match, p=4, variant="tile"):
    with pytest.raises(HazardError, match=match):
        check_dag(tasks, p, policy, variant)


def test_trsm_before_potrf_is_raw_hazard():
    tasks, pol = _tile_mixed()
    i = _idx(tasks, "TRSM")
    tasks[0], tasks[i] = tasks[i], tasks[0]     # factor panel before POTRF
    _expect_hazard(tasks, pol, "TRSM before POTRF")


def test_duplicate_update_is_waw_hazard():
    tasks, pol = _tile_mixed()
    i = _idx(tasks, "GEMM")
    tasks.insert(i + 1, tasks[i])
    _expect_hazard(tasks, pol, "WAW: duplicate/out-of-order")


def test_dropped_promote_is_precision_hazard():
    # remove the sconv2d (lo -> hi) before the trailing hi update: the SYRK
    # then consumes a lo-stored panel tile in hi with no current copy
    tasks, pol = _tile_mixed()
    del tasks[_idx(tasks, "CONVERT", tier=HI, src_tier=LO)]
    _expect_hazard(tasks, pol, "missing dlag2s/sconv2d")


def test_dropped_demote_is_precision_hazard():
    # remove the dlag2s (hi -> lo) of the factored diagonal: the lo TRSM
    # then consumes the hi-stored diagonal tile directly
    tasks, pol = _tile_mixed()
    del tasks[_idx(tasks, "CONVERT", tier=LO, src_tier=HI)]
    _expect_hazard(tasks, pol, "without a current CONVERT")


def test_skipped_update_is_raw_hazard():
    tasks, pol = _tile_mixed()
    del tasks[_idx(tasks, "SYRK")]              # drop (1,1)'s k=0 update
    _expect_hazard(tasks, pol, "factor before update")


def test_write_after_factor_is_war_hazard():
    tasks, pol = _tile_mixed()
    tasks.append(Task("GEMM", 0, (3, 2), reads=((3, 0), (2, 0), (3, 2)),
                      tier=LO))
    _expect_hazard(tasks, pol, "WAR: update of already-factored")


def test_duplicate_factor_is_waw_hazard():
    tasks, pol = _tile_mixed()
    i = _idx(tasks, "POTRF")
    tasks.append(tasks[i])
    _expect_hazard(tasks, pol, "factored twice")


def test_noop_convert_rejected():
    tasks, pol = _tile_mixed()
    tasks.insert(1, Task("CONVERT", 0, (0, 0), tier=HI, src_tier=HI))
    _expect_hazard(tasks, pol, "no-op conversion")


def test_stale_copy_does_not_satisfy_precision_edge():
    # a write bumps the version and invalidates copies: re-using a convert
    # from before an update must fail even though the copy once existed
    pol = PrecisionPolicy.tpu(1)                # every off-diagonal tile lo
    tasks = build_dag("tile", 2, pol)
    # stream: POTRF(0,0) CONVERT(0,0)hi->lo TRSM(1,0)lo CONVERT(1,0)lo->hi
    #         SYRK(1,1) POTRF(1,1); move the promote before the TRSM write
    i_cv = _idx(tasks, "CONVERT", tier=HI, src_tier=LO)
    i_tr = _idx(tasks, "TRSM")
    assert i_tr < i_cv
    tasks.insert(i_tr, tasks.pop(i_cv))
    _expect_hazard(tasks, pol, "without a current CONVERT", p=2)


def test_missing_factor_is_completeness_hazard():
    # the trailing POTRF has no downstream reader, so only the end-of-stream
    # completeness sweep can notice it is gone
    tasks, pol = _tile_mixed()
    del tasks[_idx(tasks, "POTRF", target=(3, 3))]
    _expect_hazard(tasks, pol, "never factored")


def test_touching_dropped_tile_rejected():
    pol = POLICIES["mixed"]
    tasks = build_dag("dst", 4, pol)
    tasks.append(Task("GEMM", 0, (3, 0), reads=((3, 0),), tier=HI))
    _expect_hazard(tasks, pol, "dropped/out-of-range", variant="dst")


def test_dst_dag_refused_for_non_dst_generators():
    with pytest.raises(ValueError, match="dst_dag"):
        build_dag("tile", 4, PrecisionPolicy.dst(2))


# ---- flop_report: the costmodel/benchmarks entry point --------------------

def test_flop_report_units_and_fractions():
    rep = flop_report(512, 64, POLICIES["mixed"], "tile")   # p = 8
    assert rep["total_flops"] == pytest.approx((8**3 / 3) * 64**3)
    assert rep["hi_flops"] + rep["lo_flops"] + rep["lo2_flops"] \
        == pytest.approx(rep["total_flops"])
    assert 0.0 < rep["hi_frac"] < 1.0
    assert rep["lo2_frac"] == 0.0
    assert rep["critical_path_tasks"] == 22                 # 3p - 2
    assert rep["convert_tiles"] > 0


def test_flop_report_full_policy_is_all_hi():
    rep = flop_report(256, 64, POLICIES["full"], "panel")
    assert rep["hi_frac"] == pytest.approx(1.0)
    assert rep["lo_flops"] == 0.0 and rep["convert_tiles"] == 0.0


def test_flop_report_requires_tile_multiple():
    with pytest.raises(AssertionError):
        flop_report(100, 64, POLICIES["mixed"])
