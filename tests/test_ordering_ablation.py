"""Beyond-paper ablation: ordering quality vs mixed-precision accuracy.

The paper assumes "an appropriate ordering"; we quantify it.  A better
space-filling curve (Hilbert > Morton > none) concentrates covariance
mass near the diagonal, so the SAME diag_thick band loses less accuracy
-- i.e. better ordering buys a thinner DP band (EXPERIMENTS.md §Perf)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PrecisionPolicy, build_covariance,
                        loglik_from_factor, reference_cholesky,
                        tile_cholesky)
from repro.covariance import (ORDERINGS, apply_ordering, make_dataset,
                              random_locations, simulate_field)

N, NB = 256, 32


def _band_mass(cov, nb, t):
    """Fraction of |Sigma| mass inside the tile band |i-j| < t."""
    p = cov.shape[0] // nb
    a = np.abs(np.asarray(cov, np.float32))
    total = a.sum()
    band = 0.0
    for i in range(p):
        for j in range(max(0, i - t + 1), min(p, i + t)):
            band += a[i * nb:(i + 1) * nb, j * nb:(j + 1) * nb].sum()
    return band / total


def _orderings_data():
    key = jax.random.PRNGKey(3)
    locs = random_locations(key, N)
    z = simulate_field(jax.random.PRNGKey(4), locs, [1.0, 0.1, 0.5],
                       nu_static=0.5)
    out = {}
    for name in ("morton", "hilbert"):
        perm = ORDERINGS[name](locs)
        lo, zo = apply_ordering(locs, z, perm)
        out[name] = (lo, zo)
    # true no-structure baseline: a RANDOM permutation ("none" would be
    # raster order, which is itself spatially local)
    perm = jnp.asarray(np.random.default_rng(0).permutation(N))
    out["random"] = apply_ordering(locs, z, perm)
    return out


def test_better_ordering_concentrates_band_mass():
    data = _orderings_data()
    mass = {}
    for name, (lo, _) in data.items():
        cov = build_covariance(lo, jnp.array([1.0, 0.1, 0.5]), nu_static=0.5,
                               dtype=jnp.float32)
        mass[name] = _band_mass(cov, NB, t=2)
    assert mass["hilbert"] >= mass["morton"] * 0.98  # hilbert's locality wins
    assert mass["morton"] > mass["random"] * 1.05
    assert mass["hilbert"] > mass["random"] * 1.1


def test_better_ordering_reduces_mp_likelihood_error():
    data = _orderings_data()
    errs = {}
    pol = PrecisionPolicy.tpu(diag_thick=1)
    for name, (lo, zo) in data.items():
        cov = build_covariance(lo, jnp.array([1.0, 0.1, 0.5]), nu_static=0.5,
                               jitter=1e-5, dtype=jnp.float32)
        l_ref = reference_cholesky(cov, jnp.float32)
        l_mp = tile_cholesky(cov, NB, pol)
        ll_ref = float(loglik_from_factor(l_ref, zo))
        ll_mp = float(loglik_from_factor(l_mp, zo))
        errs[name] = abs(ll_mp - ll_ref)
    assert min(errs["hilbert"], errs["morton"]) <= errs["random"] * 1.5
