"""Dry-run machinery smoke test (subprocess: needs its own XLA_FLAGS)."""

import json
import os
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """Smallest real cell through the full dryrun path on the 256-chip
    mesh: lower + compile + memory/cost analysis + roofline JSON."""
    with tempfile.TemporaryDirectory() as out:
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--cell", "whisper-tiny:decode_32k", "--mesh", "single",
             "--out", out],
            env=env, capture_output=True, text=True, timeout=1200,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert r.returncode == 0, r.stderr[-2000:]
        files = os.listdir(out)
        assert len(files) == 1
        with open(os.path.join(out, files[0])) as f:
            rep = json.load(f)
        assert rep["chips"] == 256
        assert rep["t_compute"] > 0 and rep["t_memory"] > 0
        assert rep["bottleneck"] in ("compute", "memory", "collective")
        assert "peak_bytes_per_chip" in rep["extras"]
        assert rep["extras"]["raw_compiled"]["collectives"]["count"] >= 0
