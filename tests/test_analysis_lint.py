"""Good/bad fixtures for the precision-flow linter (repro.analysis.lint).

Each rule gets a minimal snippet pair: the bad one must produce exactly the
expected finding, the good one must be clean.  The suite also pins the two
meta-properties the CI gate relies on: the repo at HEAD is lint-clean modulo
the committed baseline, and a seeded violation in a core engine is caught.
"""

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis.baseline import (
    load_baseline,
    split_baselined,
    update_baseline,
)
from repro.analysis.cli import SRC_ROOT, main, run_lint
from repro.analysis.lint import (
    Finding,
    check_kernel_package,
    lint_source,
    lint_tree,
    pragma_lines,
)

CORE = "repro/core/fixture.py"          # strict package
RUNTIME = "repro/runtime/fixture.py"    # non-strict package
KERNEL = "repro/kernels/fixture/kernel.py"


def lint(src: str, relpath: str = CORE):
    return lint_source(textwrap.dedent(src), relpath)


def rules(findings):
    return [f.rule for f in findings]


# ---- no-implicit-downcast -------------------------------------------------

def test_literal_astype_flagged_in_strict_package():
    fs = lint("x = a.astype(jnp.float32)\n")
    assert rules(fs) == ["no-implicit-downcast"]
    assert "policy-scoped" in fs[0].message


def test_string_literal_astype_flagged_in_strict_package():
    assert rules(lint('x = a.astype("float64")\n')) == ["no-implicit-downcast"]


def test_policy_field_astype_clean():
    assert lint("x = a.astype(policy.hi)\n") == []


def test_dtype_variable_astype_clean():
    assert lint("x = a.astype(dtype)\ny = b.astype(a.dtype)\n") == []


def test_widening_literal_legal_outside_strict_packages():
    # fp32 upcast is the documented MXU-accumulate idiom outside core/
    assert lint("x = a.astype(jnp.float32)\n", RUNTIME) == []


def test_narrowing_literal_flagged_everywhere():
    fs = lint("x = a.astype(jnp.bfloat16)\n", RUNTIME)
    assert rules(fs) == ["no-implicit-downcast"]
    assert "narrowing" in fs[0].message


@pytest.mark.parametrize("dt", ["float16", "float8_e4m3fn", "int8"])
def test_all_narrow_dtypes_covered(dt):
    assert rules(lint(f"x = a.astype(jnp.{dt})\n", RUNTIME)) \
        == ["no-implicit-downcast"]


# ---- pragma suppression ---------------------------------------------------

def test_inline_pragma_suppresses():
    src = ("x = a.astype(jnp.bfloat16)"
           "  # repro: disable=no-implicit-downcast -- wire format\n")
    assert lint(src, RUNTIME) == []


def test_pragma_for_other_rule_does_not_suppress():
    src = "x = a.astype(jnp.bfloat16)  # repro: disable=x64-guard\n"
    assert rules(lint(src, RUNTIME)) == ["no-implicit-downcast"]


def test_multi_rule_pragma():
    src = ("x = a.astype(jnp.bfloat16)"
           "  # repro: disable=x64-guard,no-implicit-downcast\n")
    assert lint(src, RUNTIME) == []


def test_pragma_on_any_line_of_multiline_statement():
    src = (
        "x = a.astype(\n"
        "    jnp.bfloat16\n"
        ")  # repro: disable=no-implicit-downcast -- spans three lines\n")
    assert lint(src, RUNTIME) == []


def test_pragma_parse():
    got = pragma_lines("a = 1  # repro: disable=accum-dtype, x64-guard\n")
    assert got == {1: frozenset({"accum-dtype", "x64-guard"})}


# ---- accum-dtype ----------------------------------------------------------

def test_lo_cast_operand_without_accumulator_flagged():
    src = """
    def f(a, b):
        return jnp.matmul(a.astype(jnp.bfloat16), b)
    """
    fs = lint(src, RUNTIME)
    assert "accum-dtype" in rules(fs)
    assert "preferred_element_type" in [f for f in fs
                                        if f.rule == "accum-dtype"][0].message


def test_policy_lo_cast_without_accumulator_flagged():
    src = """
    def f(a, b, policy):
        return jnp.matmul(a.astype(policy.lo), b)
    """
    assert "accum-dtype" in rules(lint(src, RUNTIME))


def test_explicit_policy_accumulator_clean():
    src = """
    def f(a, b, policy):
        al = a.astype(policy.lo)
        return jnp.matmul(al, b, preferred_element_type=policy.accum_dtype)
    """
    assert lint(src, RUNTIME) == []


def test_narrow_literal_accumulator_flagged():
    src = """
    def f(a, b):
        return jnp.matmul(a, b, preferred_element_type=jnp.bfloat16)
    """
    fs = lint(src, RUNTIME)
    assert rules(fs) == ["accum-dtype"]
    assert "narrow literal accumulator" in fs[0].message


def test_taint_through_locals():
    # dtype var bound to a lo tier, array var bound to the lo-cast value:
    # the matmul two hops away must still be flagged
    src = """
    def f(a, b, policy):
        wire = policy.lo
        aq = a.astype(wire)
        return jnp.matmul(aq, b)
    """
    assert "accum-dtype" in rules(lint(src, RUNTIME))


def test_hi_matmul_clean():
    src = """
    def f(a, b):
        return jnp.matmul(a, b)
    """
    assert lint(src, RUNTIME) == []


# ---- x64-guard ------------------------------------------------------------

def test_float64_outside_x64_module_flagged():
    fs = lint("x = jnp.float64\n", RUNTIME)
    assert rules(fs) == ["x64-guard"]
    assert "truncates" in fs[0].message


def test_float64_legal_when_module_enables_x64():
    src = """
    from jax.experimental import enable_x64
    x = jnp.float64
    """
    assert lint(src, RUNTIME) == []


def test_float64_legal_with_module_marker():
    src = """
    # repro: x64-module -- CPU statistical validation path
    x = jnp.float64
    """
    assert lint(src, RUNTIME) == []


def test_np_float64_not_flagged():
    # host-side numpy fp64 is real fp64; only jnp.float64 silently truncates
    assert lint("x = np.float64\n", RUNTIME) == []


# ---- pallas-blockspec-contract: pallas_call structure ---------------------

GOOD_PALLAS = """
def op(x):
    return pl.pallas_call(
        kern,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((128, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((256, 256), x.dtype),
    )(x)
"""


def test_good_pallas_call_clean():
    assert lint(GOOD_PALLAS, KERNEL) == []


def test_index_map_arity_vs_grid_rank():
    bad = GOOD_PALLAS.replace("lambda i, j: (i, j))]", "lambda i: (i, 0))]")
    fs = lint(bad, KERNEL)
    assert rules(fs) == ["pallas-blockspec-contract"]
    assert "grid has rank 2" in fs[0].message


def test_block_shape_rank_vs_index_rank():
    bad = GOOD_PALLAS.replace("grid=(2, 2)", "grid=(2,)") \
                     .replace("lambda i, j: (i, j)", "lambda i: i")
    fs = lint(bad, KERNEL)
    assert fs and all(f.rule == "pallas-blockspec-contract" for f in fs)
    assert any("rank 2 but its" in f.message for f in fs)


def test_out_shape_out_specs_count_mismatch():
    src = """
    def op(x):
        return pl.pallas_call(
            kern,
            grid=(2,),
            out_specs=[pl.BlockSpec((128,), lambda i: i)],
            out_shape=(jax.ShapeDtypeStruct((256,), x.dtype),
                       jax.ShapeDtypeStruct((256,), x.dtype)),
        )(x)
    """
    fs = lint(src, KERNEL)
    assert any("declares 2 outputs but out_specs declares 1" in f.message
               for f in fs)


def test_pallas_rules_only_run_in_kernels_package():
    bad = GOOD_PALLAS.replace("lambda i, j: (i, j))]", "lambda i: (i, 0))]")
    assert lint(bad, RUNTIME) == []


# ---- pallas-blockspec-contract: ops.py <-> ref.py conformance -------------

def _kernel_pkg(tmp_path, ops_src, ref_src=None):
    root = tmp_path / "repro"
    pkg = root / "kernels" / "myk"
    pkg.mkdir(parents=True)
    (pkg / "ops.py").write_text(textwrap.dedent(ops_src))
    if ref_src is not None:
        (pkg / "ref.py").write_text(textwrap.dedent(ref_src))
    return pkg, root


def test_matching_kernel_pair_clean(tmp_path):
    pkg, root = _kernel_pkg(
        tmp_path,
        "def op(a, b, *, bm=8, interpret=True):\n    return a\n",
        "def op_ref(a, b, *, bm=8):\n    return a\n")
    assert check_kernel_package(pkg, root) == []


def test_missing_ref_module_flagged(tmp_path):
    pkg, root = _kernel_pkg(tmp_path, "def op(a):\n    return a\n")
    fs = check_kernel_package(pkg, root)
    assert len(fs) == 1 and "missing ref.py" in fs[0].message


def test_positional_param_mismatch_flagged(tmp_path):
    pkg, root = _kernel_pkg(
        tmp_path,
        "def op(a, b):\n    return a\n",
        "def op_ref(a):\n    return a\n")
    fs = check_kernel_package(pkg, root)
    assert len(fs) == 1 and "positional params" in fs[0].message


def test_ref_only_keyword_flagged(tmp_path):
    pkg, root = _kernel_pkg(
        tmp_path,
        "def op(a, *, bm=8):\n    return a\n",
        "def op_ref(a, *, bm=8, scale=1.0):\n    return a\n")
    fs = check_kernel_package(pkg, root)
    assert len(fs) == 1 and "ref requires keywords ['scale']" in fs[0].message


def test_unmatched_ops_flagged(tmp_path):
    pkg, root = _kernel_pkg(
        tmp_path,
        "def op(a):\n    return a\n",
        "def other_ref(a):\n    return a\n")
    fs = check_kernel_package(pkg, root)
    assert len(fs) == 1 and "no ops.py public function" in fs[0].message


# ---- baseline mechanics ---------------------------------------------------

def _finding(code, rule="no-implicit-downcast", path="repro/x/y.py"):
    return Finding(rule, path, 3, "msg", code)


def test_baseline_rejects_todo_reasons(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "x64-guard", "path": "a.py", "code": "x = 1",
         "reason": "TODO: justify this suppression"}]}))
    with pytest.raises(ValueError, match="TODO"):
        load_baseline(p)


def test_baseline_rejects_empty_reasons(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "x64-guard", "path": "a.py", "code": "x = 1",
         "reason": "  "}]}))
    with pytest.raises(ValueError, match="empty"):
        load_baseline(p)


def test_split_matches_on_whitespace_normalized_code():
    entries = [{"rule": "no-implicit-downcast", "path": "repro/x/y.py",
                "code": "x = a.astype(jnp.bfloat16)", "reason": "legacy"}]
    f = _finding("x  =  a.astype(jnp.bfloat16)")
    new, old, unused = split_baselined([f], entries)
    assert (new, old, unused) == ([], [f], [])


def test_split_reports_new_and_unused():
    entries = [{"rule": "no-implicit-downcast", "path": "repro/x/y.py",
                "code": "gone = 1", "reason": "legacy"}]
    f = _finding("x = a.astype(jnp.bfloat16)")
    new, old, unused = split_baselined([f], entries)
    assert new == [f] and old == [] and unused == entries


def test_update_baseline_preserves_reasons(tmp_path):
    p = tmp_path / "baseline.json"
    update_baseline([_finding("x = 1")], p)
    data = json.loads(p.read_text())
    assert data["findings"][0]["reason"].startswith("TODO")
    data["findings"][0]["reason"] = "a real reason"
    p.write_text(json.dumps(data))
    update_baseline([_finding("x = 1"), _finding("y = 2")], p)
    reasons = {e["code"]: e["reason"]
               for e in json.loads(p.read_text())["findings"]}
    assert reasons["x = 1"] == "a real reason"
    assert reasons["y = 2"].startswith("TODO")


# ---- the repo itself ------------------------------------------------------

def test_repo_at_head_is_clean_modulo_baseline():
    new, _old, unused = split_baselined(lint_tree(SRC_ROOT), load_baseline())
    assert new == [], "\n".join(f.render() for f in new)
    assert unused == [], "stale baseline entries: " + repr(unused)


def test_seeded_violation_in_core_engine_is_caught():
    src = (SRC_ROOT / "core" / "tile_cholesky.py").read_text()
    assert lint_source(src, "repro/core/tile_cholesky.py") == []
    seeded = src + "\n\ndef _seeded(l_kk):\n    return l_kk.astype(jnp.float32)\n"
    fs = lint_source(seeded, "repro/core/tile_cholesky.py")
    assert rules(fs) == ["no-implicit-downcast"]


# ---- CLI gate -------------------------------------------------------------

def test_check_gate_green_at_head(capsys):
    assert main(["--check"]) == 0
    assert "static analysis: OK" in capsys.readouterr().out


def test_lint_gate_fails_on_seeded_tree(tmp_path, capsys):
    bad_root = tmp_path / "repro"
    (bad_root / "core").mkdir(parents=True)
    (bad_root / "core" / "bad.py").write_text(
        "def f(a):\n    return a.astype(jnp.float32)\n")
    assert run_lint(bad_root) == 1
    assert main(["--lint-only", "--root", str(bad_root)]) == 1
    assert "no-implicit-downcast" in capsys.readouterr().out


# ---- obs-span-context -----------------------------------------------------

def test_context_managed_span_clean():
    assert lint("with obs.span('a', x=1):\n    pass\n", RUNTIME) == []


def test_context_managed_maybe_span_with_as_clean():
    assert lint("with obs.maybe_span('a', arr) as sp:\n    pass\n",
                RUNTIME) == []


def test_bare_span_call_flagged():
    fs = lint("obs.span('a', x=1)\n", RUNTIME)
    assert rules(fs) == ["obs-span-context"]
    assert "context-managed" in fs[0].message


def test_span_assigned_to_variable_flagged():
    assert rules(lint("sp = obs.maybe_span('a', arr)\n", RUNTIME)) \
        == ["obs-span-context"]


def test_enter_context_span_clean():
    assert lint("sp = stack.enter_context(obs.span('a'))\n", RUNTIME) == []


def test_span_rule_exempt_in_obs_package():
    assert lint("def span(name):\n    return _R.span(name)\n",
                "repro/obs/fixture.py") == []


def test_span_pragma_suppresses():
    src = "obs.span('a')  # repro: disable=obs-span-context -- test\n"
    assert lint(src, RUNTIME) == []


def test_variable_named_span_not_flagged():
    # a local named `span` that is never *called* is not a telemetry leak
    assert lint("span = (hi - lo) * 0.4\n", RUNTIME) == []


# ---- stale-baseline gate (PR 10) ------------------------------------------

def _stale_entry(rule="no-implicit-downcast"):
    return {"rule": rule, "path": "repro/x/gone.py",
            "code": "x = a.astype(jnp.bfloat16)", "reason": "legacy"}


def test_stale_baseline_entry_fails_check(monkeypatch, capsys):
    from repro.analysis import cli

    monkeypatch.setattr(cli, "load_baseline",
                        lambda: load_baseline() + [_stale_entry()])
    assert cli.run_lint(SRC_ROOT) == 1
    out = capsys.readouterr().out
    assert "STALE BASELINE" in out and "gone.py" in out


def test_allow_stale_baseline_downgrades_to_note(monkeypatch, capsys):
    from repro.analysis import cli

    monkeypatch.setattr(cli, "load_baseline",
                        lambda: load_baseline() + [_stale_entry()])
    assert cli.run_lint(SRC_ROOT, allow_stale=True) == 0
    out = capsys.readouterr().out
    assert "note" in out and "STALE BASELINE" not in out


def test_inactive_rule_entries_never_stale(monkeypatch):
    """A lockguard-rule entry is not stale in a lint-only run (the rule
    didn't execute), but IS stale once --concurrency runs it."""
    from repro.analysis import cli

    monkeypatch.setattr(
        cli, "load_baseline",
        lambda: load_baseline() + [_stale_entry(rule="guarded-by")])
    assert cli.run_lint(SRC_ROOT) == 0                      # rule inactive
    assert cli.run_lint(SRC_ROOT, concurrency=True) == 1    # rule active


def test_concurrency_only_cli_flags(capsys):
    assert main(["--concurrency-only", "--verbose"]) == 0
    out = capsys.readouterr().out
    assert "hb:" in out and "interleave:" in out
    assert "static analysis: OK" in out
