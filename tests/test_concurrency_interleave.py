"""Interleaving model checker (repro.analysis.concurrency.interleave).

Pins determinism-from-config, the safety invariants (use-before-publish,
write-once, deadlock) on injected mutants, bitwise equality of every
explored interleaving with sequential replay, and the `SchedConfig.seed`
tie-break plumbing the explorer shares with the real executor.  The full
matrix (the CLI gate's >= 200 distinct interleavings) runs under the
`concurrency` marker.
"""

import dataclasses

import pytest

from repro.analysis.concurrency.interleave import (
    FAST_CELLS,
    InterleaveViolation,
    SCHEDULES,
    bitwise_equal,
    explore,
    replay_inorder,
    run_matrix,
    values_bitwise_equal,
)
from repro.analysis.dag import successor_map
from repro.core.precision import PrecisionPolicy
from repro.sched.config import SchedConfig
from repro.sched.kernels import make_kernels
from repro.sched.runtime import build_graph, priority_keys
from repro.verify.generators import spd_matrix

P, NB = 3, 4
POLICY = PrecisionPolicy.tpu(1)


@pytest.fixture(scope="module")
def cell():
    graph = build_graph("tile", P, POLICY)
    a = spd_matrix(3, P * NB, cond=50.0)
    kernels = make_kernels("tile", a, NB, POLICY)
    return graph, kernels


def cfg(**kw):
    kw.setdefault("workers", 3)
    kw.setdefault("backend", "sim")
    return SchedConfig(**kw)


# ---- determinism ----------------------------------------------------------

def test_same_config_same_interleaving(cell):
    graph, kernels = cell
    a = explore(graph, kernels, cfg(seed=5), schedule="random", salt=2)
    b = explore(graph, kernels, cfg(seed=5), schedule="random", salt=2)
    assert a.signature == b.signature
    assert a.dispatch == b.dispatch


def test_salts_diversify_interleavings(cell):
    graph, kernels = cell
    sigs = {explore(graph, kernels, cfg(seed=1), schedule="random",
                    salt=s).signature for s in range(8)}
    assert len(sigs) >= 2


def test_unknown_schedule_rejected(cell):
    graph, kernels = cell
    with pytest.raises(ValueError, match="unknown schedule"):
        explore(graph, kernels, cfg(), schedule="chaos")


# ---- every schedule reproduces sequential replay bitwise ------------------

@pytest.mark.parametrize("schedule", SCHEDULES)
def test_schedules_bitwise_equal_to_replay(cell, schedule):
    graph, kernels = cell
    reference = replay_inorder(graph, kernels)
    res = explore(graph, kernels, cfg(seed=3), schedule=schedule)
    assert res.n_steps == 3 * graph.n          # pop+compute+publish per task
    assert sorted(res.dispatch) == list(range(graph.n))
    assert values_bitwise_equal(res.values, reference) == []


def test_bitwise_equal_is_strict():
    import numpy as np

    assert bitwise_equal(np.float32(1.0), np.float32(1.0))
    assert not bitwise_equal(np.float32(1.0), np.float64(1.0))   # dtype
    assert not bitwise_equal(np.zeros(2), np.zeros((2, 1)))      # shape
    assert not bitwise_equal(np.float32(0.0), np.float32(-0.0))  # bits


# ---- mutants trip the safety invariants -----------------------------------

def _with_deps(graph, deps):
    succs = tuple(tuple(s) for s in successor_map([list(r) for r in deps]))
    return dataclasses.replace(
        graph, deps=tuple(tuple(r) for r in deps), succs=succs)


def test_dropped_edge_caught_as_use_before_publish(cell):
    """A scheduler missing one dependency edge releases a consumer early;
    the stepper's operand fetch must catch it on some explored schedule."""
    graph, kernels = cell
    caught = 0
    for task in range(graph.n):
        producers = sorted({d for d in graph.deps[task] if d >= 0})
        if not producers:
            continue
        deps = [list(r) for r in graph.deps]
        deps[task] = [d for d in deps[task] if d != producers[-1]]
        mutant = _with_deps(graph, deps)
        try:
            for schedule in SCHEDULES:
                for salt in range(4):
                    explore(mutant, kernels, cfg(seed=1),
                            schedule=schedule, salt=salt)
        except InterleaveViolation as e:
            assert ("use-before-publish" in str(e)
                    or "arity mismatch" in str(e))
            caught += 1
    assert caught > 0, "no dropped-edge mutant tripped the stepper"


def test_cycle_caught_as_deadlock(cell):
    graph, kernels = cell
    deps = [list(r) for r in graph.deps]
    deps[0] = [graph.n - 1]          # first task waits on the last: cycle
    mutant = _with_deps(graph, deps)
    with pytest.raises(InterleaveViolation, match="deadlock"):
        explore(mutant, kernels, cfg(), schedule="random")


def test_duplicate_ready_insertion_caught_as_write_once(cell):
    """A queue that enqueues a task twice publishes twice: write-once."""
    graph, kernels = cell
    # duplicate succ entry makes ndeps go negative / double-publish paths
    deps = [list(r) for r in graph.deps]
    succs = [list(s) for s in successor_map(deps)]
    # give task 0 a second root-entry by making a copy of it depend on
    # nothing: simplest faithful mutant is a graph whose succs contain a
    # duplicate, driving ndeps below zero on publish
    target = next(i for i in range(graph.n)
                  if any(d >= 0 for d in graph.deps[i]))
    producer = next(d for d in graph.deps[target] if d >= 0)
    succs[producer].append(target)
    mutant = dataclasses.replace(
        graph, succs=tuple(tuple(s) for s in succs))
    with pytest.raises(InterleaveViolation,
                       match="write-once|negative"):
        for salt in range(8):
            explore(mutant, kernels, cfg(seed=1), schedule="random",
                    salt=salt)


# ---- seed plumbing --------------------------------------------------------

def test_seed_zero_keeps_historical_tie_order():
    graph = build_graph("tile", 4, POLICY)
    k0 = priority_keys(graph, cfg(priority="critical_path", seed=0))
    k0b = priority_keys(graph, cfg(priority="critical_path"))
    assert k0 == k0b


def test_seed_permutes_ties_deterministically():
    graph = build_graph("tile", 4, POLICY)
    k7 = priority_keys(graph, cfg(priority="critical_path", seed=7))
    k7b = priority_keys(graph, cfg(priority="critical_path", seed=7))
    k9 = priority_keys(graph, cfg(priority="critical_path", seed=9))
    assert k7 == k7b
    assert k7 != k9 or k7 != priority_keys(
        graph, cfg(priority="critical_path", seed=0))
    # the task index stays the last key element (the pop contract)
    assert all(k[-1] == i for i, k in enumerate(k7))


def test_seed_validation():
    with pytest.raises(ValueError, match="seed"):
        SchedConfig(seed=-1)
    with pytest.raises(ValueError, match="seed"):
        SchedConfig(seed=1.5)
    with pytest.raises(ValueError, match="seed"):
        SchedConfig(seed=True)


def test_seeded_executor_matches_seed0_bitwise(cell):
    """Tie-break permutation changes the schedule, never the bits."""
    graph, kernels = cell
    base = explore(graph, kernels, cfg(seed=0), schedule="random")
    other = explore(graph, kernels, cfg(seed=23), schedule="random")
    assert values_bitwise_equal(other.values, base.values) == []


# ---- the matrix gate ------------------------------------------------------

def test_fast_matrix_cell_clean():
    rep = run_matrix(cells=(("tile", "mixed", 3),), seeds=4, workers=(2,))
    assert rep.ok, rep.render()
    assert rep.n_runs > 0 and rep.n_distinct > 1


@pytest.mark.concurrency
def test_full_fast_matrix_reaches_distinct_floor():
    from repro.analysis.cli import INTERLEAVE_DISTINCT_MIN

    rep = run_matrix(cells=FAST_CELLS)
    assert rep.ok, rep.render()
    assert rep.n_distinct >= INTERLEAVE_DISTINCT_MIN


@pytest.mark.concurrency
def test_full_matrix_more_workers_and_priorities():
    for priority in ("fifo", "panel_first"):
        rep = run_matrix(cells=(("tile", "mixed", 4),
                                ("tile", "three_tier", 4)),
                         seeds=6, workers=(2, 4), priority=priority)
        assert rep.ok, rep.render()
