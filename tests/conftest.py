"""Shared test fixtures. NOTE: no XLA_FLAGS here -- tests see 1 CPU device;
only launch/dryrun.py forces 512 placeholder devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.covariance import make_dataset

# hypothesis is an optional extra (pip install '.[test]'); property-based
# tests guard themselves on this flag so the deterministic tests in the
# same modules always run
try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
HYPOTHESIS_SKIP_REASON = "property test needs hypothesis (pip install '.[test]')"


@pytest.fixture(scope="session")
def small_dataset():
    """n=256 medium-correlation exponential-kernel dataset, Morton ordered."""
    key = jax.random.PRNGKey(7)
    return make_dataset(key, 256, [1.0, 0.1, 0.5], nu_static=0.5)


@pytest.fixture(scope="session")
def small_cov(small_dataset):
    from repro.core import build_covariance
    return build_covariance(small_dataset.locs, small_dataset.theta0,
                            nu_static=0.5, jitter=1e-5, dtype=jnp.float32)


def spd_matrix(key, n, dtype=jnp.float32, cond=100.0):
    """Random SPD matrix with controlled condition number.

    Thin wrapper over the canonical generator in repro.verify so tests and
    the conformance sweep draw from the same problem distribution.
    """
    from repro.verify.generators import spd_matrix as _spd
    return _spd(key, n, cond=cond, dtype=dtype)


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite src/repro/verify/golden/accuracy.json from this "
             "machine's conformance sweep instead of gating against it")
