"""Degenerate-input coverage for covariance/ordering.py.

The orderings are preprocessing for the banded factorization: whatever the
location set looks like -- duplicate coordinates, a single point, collinear
points -- the result must be a valid permutation (bijective indices, no
crash), or the downstream tile split silently drops/doubles observations.
"""

import numpy as np
import pytest

from repro.covariance.ordering import (
    ORDERINGS,
    apply_ordering,
    hilbert_order,
    morton_order,
)

ALL_ORDERINGS = sorted(ORDERINGS)


def _assert_valid_permutation(perm, n):
    perm = np.asarray(perm)
    assert perm.shape == (n,)
    assert np.array_equal(np.sort(perm), np.arange(n)), \
        "ordering must be a bijection over location indices"


@pytest.mark.parametrize("name", ALL_ORDERINGS)
def test_duplicate_coordinates(name):
    rng = np.random.default_rng(0)
    base = rng.uniform(0.05, 0.95, size=(8, 2))
    locs = np.concatenate([base, base, base[:4]])       # heavy duplication
    _assert_valid_permutation(ORDERINGS[name](locs), len(locs))


@pytest.mark.parametrize("name", ALL_ORDERINGS)
def test_all_identical_coordinates(name):
    locs = np.full((16, 2), 0.5)
    _assert_valid_permutation(ORDERINGS[name](locs), 16)


@pytest.mark.parametrize("name", ALL_ORDERINGS)
def test_single_location(name):
    locs = np.array([[0.25, 0.75]])
    perm = ORDERINGS[name](locs)
    _assert_valid_permutation(perm, 1)
    lo, zo = apply_ordering(locs, np.array([3.0]), perm)
    assert np.allclose(np.asarray(lo), locs)
    assert np.allclose(np.asarray(zo), [3.0])


@pytest.mark.parametrize("name", ALL_ORDERINGS)
@pytest.mark.parametrize("axis", [0, 1])
def test_collinear_points(name, axis):
    n = 32
    locs = np.zeros((n, 2))
    locs[:, axis] = np.linspace(0.01, 0.99, n)
    locs[:, 1 - axis] = 0.4
    perm = ORDERINGS[name](locs)
    _assert_valid_permutation(perm, n)
    if name in ("morton", "hilbert"):
        # along a line, a space-filling-curve order must keep neighbours
        # near each other: the sorted coordinate should be monotone up to
        # curve folds -- at minimum, no crash and locality is preserved on
        # average vs a random shuffle
        coord = locs[np.asarray(perm), axis]
        jumps = np.abs(np.diff(coord)).mean()
        assert jumps <= 0.5, "curve order scatters collinear points"


@pytest.mark.parametrize("name", ["morton", "hilbert"])
def test_boundary_coordinates_clamped(name):
    # exactly 0.0 and 1.0 (and slightly outside) must not wrap the integer
    # quantization used by the curve keys
    locs = np.array([[0.0, 0.0], [1.0, 1.0], [-0.01, 0.5], [0.5, 1.01]])
    _assert_valid_permutation(ORDERINGS[name](locs), len(locs))


def test_duplicates_order_stable_hilbert():
    # stable sort: duplicate keys keep input order (documented np.argsort
    # kind="stable" in hilbert_order)
    locs = np.full((5, 2), 0.3)
    perm = np.asarray(hilbert_order(locs))
    assert np.array_equal(perm, np.arange(5))


def test_morton_matches_manual_quadrants():
    # sanity anchor: four quadrant points sort in Z order
    locs = np.array([[0.9, 0.9], [0.1, 0.1], [0.9, 0.1], [0.1, 0.9]])
    perm = np.asarray(morton_order(locs))
    assert perm[0] == 1  # lower-left first on the Z curve
