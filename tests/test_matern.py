"""Matern covariance + Bessel K_nu correctness (vs scipy) and properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.special as sp

from conftest import HAVE_HYPOTHESIS, HYPOTHESIS_SKIP_REASON

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    import strategies as sts

from repro.covariance import kv, matern, matern_covariance, pairwise_distance


NUS = [0.1, 0.3, 0.5, 0.9, 1.0, 1.27, 1.5, 2.0, 2.5, 3.3, 4.9, 7.2]
XS = np.array([1e-4, 1e-2, 0.1, 0.5, 1.0, 1.9, 2.0, 2.1, 3.0, 5.0, 10.0, 30.0, 80.0])


@pytest.mark.parametrize("nu", NUS)
def test_kv_matches_scipy_f64(nu):
    with jax.experimental.enable_x64():
        ours = np.asarray(kv(jnp.float64(nu), jnp.asarray(XS, jnp.float64)))
    ref = sp.kv(nu, XS)
    np.testing.assert_allclose(ours, ref, rtol=1e-10)


def test_kv_f32_reasonable():
    ours = np.asarray(kv(jnp.float32(1.27), jnp.asarray(XS, jnp.float32)))
    ref = sp.kv(1.27, XS)
    np.testing.assert_allclose(ours, ref, rtol=2e-4)


@pytest.mark.parametrize("nu", [0.5, 1.5, 2.5])
def test_matern_closed_form_matches_general(nu):
    theta = jnp.array([1.3, 0.2, nu])
    r = jnp.linspace(0.0, 2.0, 64)
    a = matern(r, theta, nu_static=nu)
    b = matern(r, theta)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)


def test_matern_at_zero_is_variance():
    theta = jnp.array([2.7, 0.1, 1.1])
    assert float(matern(jnp.array(0.0), theta)) == pytest.approx(2.7, rel=1e-6)


def test_matern_monotone_decreasing():
    theta = jnp.array([1.0, 0.2, 0.8])
    r = jnp.linspace(0.0, 3.0, 100)
    c = np.asarray(matern(r, theta))
    assert np.all(np.diff(c) <= 1e-7)


def test_matern_gradients_finite():
    f = lambda th: matern(jnp.array(0.3), th)[()]
    g = jax.grad(f)(jnp.array([1.0, 0.1, 1.27]))
    assert np.all(np.isfinite(np.asarray(g)))


if HAVE_HYPOTHESIS:
    @given(sts.matern_nus, sts.bessel_args)
    @settings(max_examples=30, deadline=None)
    def test_kv_positive_and_decreasing_in_x(nu, x):
        v1 = float(kv(nu, jnp.float32(x)))
        v2 = float(kv(nu, jnp.float32(x * 1.1)))
        assert v1 > 0 and v2 > 0 and v2 <= v1 * (1 + 1e-5)
else:
    @pytest.mark.skip(reason=HYPOTHESIS_SKIP_REASON)
    def test_kv_positive_and_decreasing_in_x():
        pass


def test_pairwise_euclidean():
    a = jnp.array([[0.0, 0.0], [1.0, 0.0]])
    d = pairwise_distance(a, a)
    np.testing.assert_allclose(np.asarray(d), [[0, 1], [1, 0]], atol=1e-6)


def test_pairwise_haversine_symmetry_and_scale():
    a = jnp.array([[40.0, 20.0], [41.0, 20.0], [40.0, 21.0]])
    d = np.asarray(pairwise_distance(a, a, metric="haversine"))
    assert d[0, 0] == pytest.approx(0.0, abs=1e-5)
    np.testing.assert_allclose(d, d.T, atol=1e-5)
    # 1 degree of longitude at lat 20 ~ cos(20 deg) degrees of arc
    assert d[0, 1] == pytest.approx(np.cos(np.deg2rad(20.0)), rel=1e-3)
    assert d[0, 2] == pytest.approx(1.0, rel=1e-3)  # 1 degree of latitude


def test_covariance_is_spd(small_dataset):
    cov = matern_covariance(small_dataset.locs, small_dataset.locs,
                            jnp.array([1.0, 0.1, 0.5]), nu_static=0.5)
    evals = np.linalg.eigvalsh(np.asarray(cov, np.float64))
    assert evals.min() > -1e-5
