"""Fast unit tests for the verify/ subsystem itself.

The conformance sweep (test_conformance_sweep.py) trusts generators,
oracles, the bound registry, and the golden gate; these tests establish
that trust cheaply -- no sweep, every case well under a second.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.verify import (
    AccuracyBound,
    CholeskyProblem,
    backward_error,
    compare_to_golden,
    dtype_pair,
    exact_factor,
    exact_kriging_pmse,
    exact_loglik,
    loglik_drift,
    lookup_bound,
    matern_problem,
    policy_bound,
    rel_frobenius,
    save_golden,
    spd_matrix,
)
from repro.verify.golden import load_golden
from repro.core.precision import PrecisionPolicy


# ---- generators -----------------------------------------------------------

def test_spd_matrix_deterministic_and_conditioned():
    a = np.asarray(spd_matrix(3, 64, cond=1e4), np.float64)
    b = np.asarray(spd_matrix(3, 64, cond=1e4), np.float64)
    np.testing.assert_array_equal(a, b)
    # symmetric to fp32 rounding at the matrix's own scale
    assert np.abs(a - a.T).max() < 1e-6 * np.abs(a).max()
    eigs = np.linalg.eigvalsh(a)
    assert eigs.min() > 0
    # the spectrum is exactly log-spaced, so cond hits the target
    assert eigs.max() / eigs.min() == pytest.approx(1e4, rel=1e-2)


def test_spd_matrix_accepts_prng_key():
    np.testing.assert_array_equal(
        np.asarray(spd_matrix(jax.random.PRNGKey(5), 32)),
        np.asarray(spd_matrix(jax.random.PRNGKey(5), 32)))


def test_matern_problem_deterministic_and_spd():
    p1 = matern_problem(64, "strong")
    p2 = matern_problem(64, "strong")
    np.testing.assert_array_equal(np.asarray(p1.cov), np.asarray(p2.cov))
    np.testing.assert_array_equal(np.asarray(p1.z), np.asarray(p2.z))
    assert isinstance(p1, CholeskyProblem)
    assert p1.p == 64 // p1.nb
    assert p1.name == "n64_strong"
    evals = np.linalg.eigvalsh(np.asarray(p1.cov, np.float64))
    assert evals.min() > 0


def test_matern_regimes_differ():
    weak = matern_problem(64, "weak")
    strong = matern_problem(64, "strong")
    # stronger correlation -> more off-diagonal mass
    off = lambda p: np.abs(np.asarray(p.cov, np.float64)
                           - np.diag(np.diag(p.cov))).sum()
    assert off(strong) > off(weak)


# ---- oracles --------------------------------------------------------------

def test_exact_factor_matches_numpy_f64():
    a = spd_matrix(1, 32, cond=100.0)
    l = exact_factor(a)
    assert l.dtype == np.float64
    # jax and numpy block the fp64 factorization differently; agreement is
    # to accumulated-rounding scale, far below any registry bound
    np.testing.assert_allclose(
        l, np.linalg.cholesky(np.asarray(a, np.float64)),
        rtol=1e-5, atol=1e-6)


def test_exact_loglik_matches_direct_formula():
    a = spd_matrix(2, 32, cond=10.0)
    z = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (32,)))
    a64 = np.asarray(a, np.float64)
    sign, logdet = np.linalg.slogdet(a64)
    direct = (-0.5 * 32 * np.log(2 * np.pi) - 0.5 * logdet
              - 0.5 * z @ np.linalg.solve(a64, z))
    assert exact_loglik(a, z) == pytest.approx(direct, rel=1e-12)


def test_exact_kriging_pmse_zero_when_truth_is_prediction():
    a = spd_matrix(4, 32, cond=10.0)
    z = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (32,)))
    sigma_no = np.asarray(a, np.float64)[:4, :]   # predict 4 "new" points
    mu = sigma_no @ np.linalg.solve(np.asarray(a, np.float64), z)
    assert exact_kriging_pmse(a, z, sigma_no, mu) == pytest.approx(0.0, abs=1e-18)


def test_error_metrics_zero_on_exact_inputs():
    a = spd_matrix(7, 32, cond=10.0)
    l = exact_factor(a)
    assert rel_frobenius(l, l) == 0.0
    assert backward_error(l, a) < 1e-7      # fp32 input, fp64 factor
    assert loglik_drift(-123.456, -123.456) == 0.0


def test_loglik_drift_normalization():
    # |ref| < 1 -> absolute scale; large |ref| -> relative scale
    assert loglik_drift(0.3, 0.1) == pytest.approx(0.2)
    assert loglik_drift(-1010.0, -1000.0) == pytest.approx(0.01)


# ---- bounds registry ------------------------------------------------------

def test_dtype_pair_labels():
    assert dtype_pair(PrecisionPolicy.full(jnp.float32)) == "f32"
    assert dtype_pair(PrecisionPolicy.tpu(1)) == "f32/bf16"
    assert dtype_pair(PrecisionPolicy.paper_cpu(1)) == "f64/f32"
    assert dtype_pair(PrecisionPolicy.three_tier(1, 2)) == "f32/bf16/f8e4m3"
    assert dtype_pair(PrecisionPolicy.dst(2)) == "f32/zero"


def test_lookup_prefers_most_specific_key():
    generic = lookup_bound("mixed", "f32/bf16", 2, "strong")
    weak = lookup_bound("mixed", "f32/bf16", 2, "weak")
    # the regime-specific weak entry is strictly tighter than the fallback
    assert weak.factor_rel < generic.factor_rel


def test_lookup_unknown_mode_raises():
    with pytest.raises(KeyError, match="no registered bound"):
        lookup_bound("quantum", "f4/f2")


def test_policy_bound_roundtrip():
    pol = PrecisionPolicy.tpu(2)
    assert policy_bound(pol, "weak") is lookup_bound("mixed", "f32/bf16",
                                                     2, "weak")


def test_bound_violations():
    bound = AccuracyBound(factor_rel=1e-3, loglik_drift=1e-4)
    assert bound.violations({"factor_rel": 1e-4, "loglik_drift": 1e-5}) == []
    msgs = bound.violations({"factor_rel": 1e-2, "loglik_drift": 1e-5})
    assert len(msgs) == 1 and "factor_rel" in msgs[0]
    # metrics without a registered limit are ignored
    assert bound.violations({"pmse_rel": 1e9}) == []


def test_bound_flags_nan_as_violation():
    bound = AccuracyBound(factor_rel=1e-3)
    msgs = bound.violations({"factor_rel": float("nan")})
    assert len(msgs) == 1 and "non-finite" in msgs[0]
    msgs = bound.violations({"factor_rel": math.inf})
    assert len(msgs) == 1 and "non-finite" in msgs[0]


# ---- golden gate ----------------------------------------------------------

RECORDS = [
    {"id": "chol/a", "factor_rel": 1e-4, "loglik_drift": 1e-5},
    {"id": "kern/b", "max_abs": 1e-3},
]


def test_golden_roundtrip_and_clean_compare(tmp_path):
    path = save_golden(RECORDS, tmp_path / "g.json")
    golden = load_golden(path)
    assert set(golden["records"]) == {"chol/a", "kern/b"}
    assert compare_to_golden(RECORDS, golden) == []


def test_golden_detects_drift(tmp_path):
    golden = load_golden(save_golden(RECORDS, tmp_path / "g.json"))
    moved = [dict(RECORDS[0], factor_rel=3e-4), RECORDS[1]]  # 3x > 2x slack
    drifts = compare_to_golden(moved, golden)
    assert len(drifts) == 1
    assert drifts[0][0] == "chol/a" and "drifted" in drifts[0][1]
    # within slack -> clean
    ok = [dict(RECORDS[0], factor_rel=1.5e-4), RECORDS[1]]
    assert compare_to_golden(ok, golden) == []


def test_golden_floor_absorbs_noise_near_zero(tmp_path):
    gold = [{"id": "kern/exact", "max_rel": 0.0}]
    golden = load_golden(save_golden(gold, tmp_path / "g.json"))
    # 0 * slack = 0, but the 1e-6 floor keeps epsilon-noise from flaking
    assert compare_to_golden([{"id": "kern/exact", "max_rel": 1e-8}],
                             golden) == []
    drifts = compare_to_golden([{"id": "kern/exact", "max_rel": 1e-3}], golden)
    assert len(drifts) == 1


def test_golden_flags_coverage_changes(tmp_path):
    golden = load_golden(save_golden(RECORDS, tmp_path / "g.json"))
    drifts = compare_to_golden(RECORDS + [{"id": "new", "max_abs": 0.1}],
                               golden)
    assert [d[0] for d in drifts] == ["new"]
    drifts = compare_to_golden(RECORDS[:1], golden)
    assert [d[0] for d in drifts] == ["kern/b"]
    assert "coverage lost" in drifts[0][1]
