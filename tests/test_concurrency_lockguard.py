"""Lock-discipline linter (repro.analysis.concurrency.lockguard).

Each rule gets a bad/good snippet pair; the repo's annotated sources at
HEAD must be clean; and a seeded mutant of the real executor (one
``with state.cond:`` removed) must be caught -- the meta-property the CI
gate relies on.
"""

import textwrap
from pathlib import Path

import pytest

from repro.analysis.concurrency.lockguard import (
    LOCKGUARD_FILES,
    LOCKGUARD_RULES,
    guarded_registry,
    lockguard_files,
    lockguard_source,
)
from repro.analysis.cli import SRC_ROOT


def lint(src: str):
    return lockguard_source(textwrap.dedent(src), "repro/fixture.py")


def rules(findings):
    return [f.rule for f in findings]


GUARDED = """
import threading

class S:
    def __init__(self):
        self.lock = threading.Lock()
        self.items = []        # repro: guarded-by=lock
        self.count = 0         # repro: guarded-by=lock
"""


# ---- registry -------------------------------------------------------------

def test_registry_extracted_from_annotations():
    reg = guarded_registry(textwrap.dedent(GUARDED))
    assert reg == {"items": "lock", "count": "lock"}


def test_registry_empty_without_annotations():
    assert guarded_registry("x = 1\n") == {}


# ---- guarded-by -----------------------------------------------------------

def test_unguarded_append_flagged():
    fs = lint(GUARDED + """
    def add(self, x):
        self.items.append(x)
""")
    assert rules(fs) == ["guarded-by"]
    assert "items" in fs[0].message


def test_unguarded_assignment_flagged():
    fs = lint(GUARDED + """
    def bump(self):
        self.count += 1
""")
    assert rules(fs) == ["guarded-by"]


def test_unguarded_subscript_flagged():
    fs = lint(GUARDED + """
    def set(self, i, v):
        self.items[i] = v
""")
    assert rules(fs) == ["guarded-by"]


def test_unguarded_heappush_flagged():
    fs = lint("import heapq\n" + GUARDED + """
    def push(self, x):
        heapq.heappush(self.items, x)
""")
    assert rules(fs) == ["guarded-by"]


def test_guarded_mutation_clean():
    assert lint(GUARDED + """
    def add(self, x):
        with self.lock:
            self.items.append(x)
            self.count += 1
""") == []


def test_init_exempt():
    """Construction happens-before publication: __init__ needs no lock."""
    assert lint(GUARDED) == []


def test_locked_helper_exempt_but_call_site_checked():
    src = GUARDED + """
    def _add_locked(self, x):
        self.items.append(x)

    def good(self, x):
        with self.lock:
            self._add_locked(x)

    def bad(self, x):
        self._add_locked(x)
"""
    fs = lint(src)
    assert rules(fs) == ["guarded-by"]
    assert "_add_locked" in fs[0].message


def test_condition_guards_cond_annotated_attrs():
    """`with state.cond:` satisfies guarded-by=cond (Condition over lock)."""
    assert lint("""
import threading

class St:
    def __init__(self):
        self.cond = threading.Condition()
        self.q = []    # repro: guarded-by=cond

def worker(state):
    with state.cond:
        state.q.append(1)
""") == []


def test_pragma_suppresses():
    fs = lint(GUARDED + """
    def add(self, x):
        self.items.append(x)  # repro: disable=guarded-by -- test fixture
""")
    assert fs == []


# ---- cv-wait-loop ---------------------------------------------------------

def test_if_guarded_wait_flagged():
    fs = lint("""
import threading

class S:
    def __init__(self):
        self.cond = threading.Condition()
        self.q = []    # repro: guarded-by=cond

    def get(self):
        with self.cond:
            if not self.q:
                self.cond.wait()
            return self.q.pop()  # repro: disable=guarded-by -- fixture
""")
    assert rules(fs) == ["cv-wait-loop"]


def test_while_guarded_wait_clean():
    assert lint("""
import threading

class S:
    def __init__(self):
        self.cond = threading.Condition()
        self.q = []    # repro: guarded-by=cond

    def get(self):
        with self.cond:
            while not self.q:
                self.cond.wait()
""") == []


def test_wait_for_clean():
    """Condition.wait_for re-checks its predicate internally."""
    assert lint("""
import threading

class S:
    def __init__(self):
        self.cond = threading.Condition()
        self.q = []    # repro: guarded-by=cond

    def get(self):
        with self.cond:
            self.cond.wait_for(lambda: self.q)
""") == []


# ---- lock-dispatch --------------------------------------------------------

def test_jnp_call_under_lock_flagged():
    fs = lint("""
import threading
import jax.numpy as jnp

class S:
    def __init__(self):
        self.lock = threading.Lock()
        self.out = []    # repro: guarded-by=lock

    def work(self, x):
        with self.lock:
            self.out.append(jnp.tril(x))
""")
    assert rules(fs) == ["lock-dispatch"]


def test_block_until_ready_under_lock_flagged():
    fs = lint("""
import threading

class S:
    def __init__(self):
        self.lock = threading.Lock()
        self.out = []    # repro: guarded-by=lock

    def work(self, y):
        with self.lock:
            y.block_until_ready()
""")
    assert rules(fs) == ["lock-dispatch"]


def test_kernels_run_under_lock_flagged():
    fs = lint("""
import threading

class S:
    def __init__(self):
        self.lock = threading.Lock()
        self.out = []    # repro: guarded-by=lock

def work(state, kernels, task, ops):
    with state.lock:
        state.out.append(kernels.run(task, ops))
""")
    assert rules(fs) == ["lock-dispatch"]


def test_dispatch_outside_lock_clean():
    assert lint("""
import threading
import jax.numpy as jnp

class S:
    def __init__(self):
        self.lock = threading.Lock()
        self.out = []    # repro: guarded-by=lock

    def work(self, x):
        y = jnp.tril(x)
        with self.lock:
            self.out.append(y)
""") == []


def test_dispatch_under_unregistered_lock_clean():
    """Only locks named by the guarded-by registry serialize the pool."""
    assert lint("""
import threading
import jax.numpy as jnp

other = threading.Lock()

def work(x):
    with other:
        return jnp.tril(x)
""") == []


# ---- the repo itself ------------------------------------------------------

def test_repo_sources_clean():
    assert lockguard_files(SRC_ROOT) == []


def test_registered_files_have_annotations():
    for rel in LOCKGUARD_FILES:
        src = (SRC_ROOT.parent / rel).read_text()
        assert guarded_registry(src), f"{rel} lost its guarded-by registry"


def test_missing_registered_file_is_a_finding(tmp_path):
    fake_root = tmp_path / "repro"
    fake_root.mkdir()
    fs = lockguard_files(fake_root)
    assert fs and all(f.rule == "guarded-by" for f in fs)
    assert "missing" in fs[0].message


def test_mutated_executor_caught():
    """Remove one `with state.cond:` from the real executor source: the
    mutations it guarded become findings."""
    src = (SRC_ROOT / "sched" / "runtime.py").read_text()
    needle = "with state.cond:"
    assert needle in src, "executor no longer uses `with state.cond:`"
    lines = src.splitlines(keepends=True)
    hit = next(i for i, ln in enumerate(lines) if needle in ln)
    lines[hit] = lines[hit].replace(needle, "if True:")
    mutant = "".join(lines)
    fs = lockguard_source(mutant, "repro/sched/runtime.py")
    assert fs, "removing a lock block produced no findings"
    assert {f.rule for f in fs} <= set(LOCKGUARD_RULES)
    assert any(f.rule == "guarded-by" for f in fs)


def test_mutated_recorder_caught():
    src = (SRC_ROOT / "obs" / "recorder.py").read_text()
    needle = "with self._lock:"
    # first occurrence in actual code, not the class docstring
    at = src.index(needle, src.index("def _finish"))
    mutant = src[:at] + "if True:" + src[at + len(needle):]
    fs = lockguard_source(mutant, "repro/obs/recorder.py")
    assert any(f.rule == "guarded-by" for f in fs)


# ---- baseline integration -------------------------------------------------

def test_lockguard_findings_flow_through_baseline(monkeypatch, capsys):
    """An unbaselined lockguard finding fails `--check --concurrency-only`
    via the shared lint gate (seeded by breaking a registered file)."""
    from repro.analysis import cli

    real = lockguard_files

    def broken(root, files=LOCKGUARD_FILES):
        from repro.analysis.lint import Finding
        return real(root, files) + [Finding(
            "guarded-by", "repro/sched/runtime.py", 1, "seeded", "x = 1")]

    monkeypatch.setattr(
        "repro.analysis.concurrency.lockguard.lockguard_files", broken)
    rc = cli.run_lint(SRC_ROOT, concurrency=True)
    assert rc == 1
    assert "seeded" in capsys.readouterr().out
