"""Happens-before trace verifier (repro.analysis.concurrency.hb).

Clean recorded schedules must verify with zero violations across the
conformance cells; injected mutants -- a dropped dependency edge in the
scheduler, tampered timestamps, concurrent same-slot writes -- must be
caught and named.  The Chrome-trace round-trip (otherData -> rebuilt
graph) is pinned because CI verifies the uploaded artifact standalone.
"""

import dataclasses
import json

import pytest

from repro.analysis.concurrency.hb import (
    HBError,
    verify_sched_report,
    verify_trace,
    verify_trace_file,
)
from repro.analysis.concurrency.hb import _Event, verify_events
from repro.analysis.dag import successor_map
from repro.core.precision import PrecisionPolicy
from repro.sched.config import SchedConfig
from repro.sched.runtime import build_graph, simulate
from repro.sched.trace import chrome_trace, validate_trace, write_trace

P = 6

CELLS = [
    ("tile", PrecisionPolicy.full()),
    ("tile", PrecisionPolicy.tpu(2)),
    ("tile", PrecisionPolicy.three_tier(1, 2)),
    ("panel", PrecisionPolicy.tpu(2)),
    ("dst", PrecisionPolicy.dst(2)),
]


def _sim(graph, **kw):
    kw.setdefault("workers", 3)
    kw.setdefault("backend", "sim")
    return simulate(graph, SchedConfig(**kw))


# ---- clean schedules verify -----------------------------------------------

@pytest.mark.parametrize("variant,policy", CELLS,
                         ids=[f"{v}-{p.mode}" for v, p in CELLS])
def test_clean_simulated_schedule_verifies(variant, policy):
    graph = build_graph(variant, P, policy)
    for priority in ("fifo", "panel_first", "critical_path"):
        for seed in (0, 11):
            rep = verify_sched_report(
                _sim(graph, priority=priority, seed=seed), graph)
            assert rep.ok, rep.render()
            assert rep.n_events == graph.n
            assert rep.n_dep_edges > 0 and rep.n_po_edges > 0


def test_report_metadata_enough_without_graph():
    """SchedReport carries (variant, p, policy): no explicit graph needed."""
    graph = build_graph("tile", P, PrecisionPolicy.tpu(2))
    rep = verify_sched_report(_sim(graph))
    assert rep.ok and rep.variant == "tile" and rep.p == P


def test_trace_roundtrip_verifies(tmp_path):
    graph = build_graph("tile", P, PrecisionPolicy.three_tier(1, 2))
    report = _sim(graph, workers=4)
    trace = chrome_trace(report)
    validate_trace(trace)
    assert verify_trace(trace).ok

    path = tmp_path / "trace.json"
    write_trace(report, path)
    assert verify_trace_file(path).ok


def test_trace_without_metadata_rejected():
    trace = {"traceEvents": [], "otherData": {"variant": "tile"}}
    with pytest.raises(HBError, match="otherData"):
        verify_trace(trace)


def test_incomplete_trace_rejected():
    graph = build_graph("tile", 3, PrecisionPolicy.full())
    trace = chrome_trace(_sim(graph))
    trace["traceEvents"] = [ev for ev in trace["traceEvents"]
                            if ev.get("args", {}).get("index") != 0]
    with pytest.raises(HBError, match="missing task indices"):
        verify_trace(trace)


def test_duplicate_event_rejected():
    graph = build_graph("tile", 3, PrecisionPolicy.full())
    trace = chrome_trace(_sim(graph))
    dup = next(ev for ev in trace["traceEvents"]
               if ev.get("args", {}).get("index") == 0)
    trace["traceEvents"].append(dict(dup))
    with pytest.raises(HBError, match="twice"):
        verify_trace(trace)


# ---- mutants are caught ---------------------------------------------------

def _drop_edge(graph, task, producer):
    """Scheduler that lost one dependency edge of `task`."""
    deps = tuple(
        tuple(d for d in row if d != producer) if i == task else row
        for i, row in enumerate(graph.deps))
    succs = tuple(tuple(s) for s in successor_map([list(r) for r in deps]))
    return dataclasses.replace(graph, deps=deps, succs=succs)


def test_dropped_edge_mutants_caught():
    """Run a buggy scheduler (one edge dropped), verify the recorded
    execution against the TRUE graph: the sweep must catch violations."""
    graph = build_graph("tile", 4, PrecisionPolicy.tpu(1))
    caught = total = 0
    for task in range(graph.n):
        producers = sorted({d for d in graph.deps[task] if d >= 0})
        if not producers:
            continue
        total += 1
        mutant = _drop_edge(graph, task, producers[0])
        rep = verify_sched_report(_sim(mutant, priority="fifo"), graph)
        if not rep.ok:
            caught += 1
            kinds = {v.kind for v in rep.violations}
            assert kinds <= {"dep-order", "convert-order", "write-write"}
    # not every drop perturbs the schedule enough to violate timestamps
    # (the HB checker judges the recorded execution, not the scheduler's
    # edge table), but most must be caught
    assert total >= 10
    assert caught >= total // 2, f"only {caught}/{total} mutants caught"


def test_dropped_convert_edge_reports_convert_order():
    """Dropping a CONVERT -> consumer edge is reported as convert-order."""
    graph = build_graph("tile", 4, PrecisionPolicy.tpu(1))
    hits = []
    for task in range(graph.n):
        for d in set(graph.deps[task]):
            if d >= 0 and graph.tasks[d].kind == "CONVERT":
                mutant = _drop_edge(graph, task, d)
                rep = verify_sched_report(_sim(mutant, priority="fifo"),
                                          graph)
                hits.extend(v.kind for v in rep.violations)
    assert "convert-order" in hits


def test_tampered_timestamp_caught():
    """Shifting one consumer's start before its producer's end is a
    dep-order violation even though the scheduler was correct."""
    graph = build_graph("tile", 4, PrecisionPolicy.full())
    report = _sim(graph)
    # pick a task with a real producer
    task = next(i for i in range(graph.n)
                if any(d >= 0 for d in graph.deps[i]))
    producer = next(d for d in graph.deps[task] if d >= 0)
    events = []
    for ev in report.events:
        if ev.index == task:
            end = report.events[[e.index for e in report.events]
                                .index(producer)].end
            ev = dataclasses.replace(ev, start=end - 1.0)
        events.append(ev)
    tampered = dataclasses.replace(report, events=tuple(events))
    rep = verify_sched_report(tampered, graph)
    assert not rep.ok
    assert any(v.kind in ("dep-order", "convert-order")
               and v.index_b == task for v in rep.violations)


def test_concurrent_same_slot_writes_caught():
    """Two writers of one tile slot on different workers with no HB path
    between them is a write-write violation."""
    graph = build_graph("tile", 3, PrecisionPolicy.full())
    # find two compute tasks writing the same tile (e.g. SYRK then POTRF
    # on a diagonal tile across steps)
    writers = {}
    pair = None
    for i, t in enumerate(graph.tasks):
        if t.kind == "CONVERT":
            continue
        if t.target in writers:
            pair = (writers[t.target], i)
            break
        writers[t.target] = i
    assert pair is not None
    a, b = pair
    # synthetic schedule: everything sequential on worker 0 in emission
    # order, except writer b runs concurrently with a on worker 1
    events = []
    for i in range(graph.n):
        if i == b:
            events.append(_Event(index=i, worker=1, worker_name="w1",
                                 start=float(a), end=float(a) + 0.5))
        else:
            events.append(_Event(index=i, worker=0, worker_name="w0",
                                 start=float(i), end=float(i) + 0.9))
    rep = verify_events(events, graph)
    assert any(v.kind == "write-write" for v in rep.violations)


def test_same_version_duplicate_converts_exempt():
    """Duplicate CONVERTs of the same source version are independent
    bitwise-identical copies: concurrent execution is not a violation."""
    graph = build_graph("tile", 6, PrecisionPolicy.tpu(2))
    dup = None
    seen = {}
    for i, t in enumerate(graph.tasks):
        if t.kind != "CONVERT":
            continue
        key = (t.target, t.tier, tuple(sorted(set(graph.deps[i]))))
        if key in seen:
            dup = (seen[key], i)
            break
        seen[key] = i
    assert dup is not None, "stream emits no duplicate CONVERT at p=6"
    rep = verify_sched_report(_sim(graph, priority="fifo"), graph)
    assert rep.ok, rep.render()


def test_hb_trace_cli_gate(tmp_path, capsys):
    from repro.analysis.cli import main

    graph = build_graph("tile", P, PrecisionPolicy.tpu(2))
    path = tmp_path / "sched-trace.json"
    write_trace(_sim(graph, workers=4), path)
    assert main(["--hb-trace", str(path)]) == 0
    assert "0 violations" in capsys.readouterr().out

    bad = json.loads(path.read_text())
    bad["otherData"].pop("policy")
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(bad))
    assert main(["--hb-trace", str(bad_path)]) == 1


# ---- real threaded execution ----------------------------------------------

def test_threaded_execution_names_workers_and_verifies():
    """The real executor's recorded schedule -- OS thread names, wall-clock
    timestamps -- passes the HB checks with zero slack."""
    from repro.sched.kernels import make_kernels
    from repro.sched.runtime import execute
    from repro.verify.generators import spd_matrix

    policy = PrecisionPolicy.tpu(2)
    graph = build_graph("tile", 4, policy)
    a = spd_matrix(5, 4 * 4, cond=50.0)
    kernels = make_kernels("tile", a, 4, policy)
    _store, report = execute(graph, SchedConfig(workers=3, backend="real"),
                             kernels)
    assert {ev.worker_name for ev in report.events} <= {
        f"sched-w{w}" for w in range(3)}
    rep = verify_sched_report(report, graph)
    assert rep.ok, rep.render()

    trace = chrome_trace(report)
    validate_trace(trace)
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {f"sched-w{w}" for w in range(3)}
    assert verify_trace(trace).ok
