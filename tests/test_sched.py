"""Dynamic-runtime tests: graph helpers, determinism, priorities, traces.

The numerical equivalence matrix lives in test_sched_equivalence.py; this
module covers the scheduler itself:

  * the shared dependency computation (`task_dependencies` /
    `successor_map` / `generations`) is structurally sound on every
    conformance-matrix cell;
  * the simulated backend is deterministic (no wall clock anywhere),
    respects the makespan lower bounds, and hits the paper-motivated
    >= 1.5x makespan reduction with 4 workers at p >= 8;
  * critical-path priority never loses to FIFO on the 3p-2-task chain;
  * every dispatch order the scheduler emits replays hazard-free through
    `check_dag` -- the static checker gates the dynamic runtime;
  * emitted Chrome traces are well-formed, monotone, and overlap-free,
    and the validator actually rejects corrupted traces.
"""

import json

import pytest

from repro.analysis.dag import (
    Task,
    build_dag,
    check_dag,
    generations,
    successor_map,
    task_dependencies,
)
from repro.core.precision import PrecisionPolicy
from repro.launch.costmodel import task_virtual_cost
from repro.sched import (
    SchedConfig,
    TaskGraph,
    build_graph,
    chrome_trace,
    downstream_cost,
    load_and_validate,
    simulate,
    simulate_dag,
    validate_trace,
    write_trace,
)

POLICIES = {
    "full": PrecisionPolicy.full(),
    "mixed": PrecisionPolicy.tpu(2),
    "three_tier": PrecisionPolicy.three_tier(1, 3),
}
VARIANTS = ("tile", "panel", "dst")
PS = (1, 4, 8)


# ---------------------------------------------------------------------------
# config validation (same eager style as PrecisionPolicy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs", [
    {"priority": "lifo"},
    {"backend": "gpu"},
    {"workers": 0},
    {"workers": 2.5},
    {"convert_cost": -1.0},
    {"convert_cost": float("nan")},
])
def test_sched_config_rejects(kwargs):
    with pytest.raises(ValueError):
        SchedConfig(**kwargs)


def test_sched_config_defaults_valid():
    cfg = SchedConfig()
    assert cfg.workers >= 1 and cfg.priority in ("fifo", "panel_first",
                                                 "critical_path")


# ---------------------------------------------------------------------------
# shared dependency computation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("label", sorted(POLICIES))
@pytest.mark.parametrize("variant", VARIANTS)
def test_dependency_structure(variant, label, p):
    policy = POLICIES[label]
    tasks = build_dag(variant, p, policy)
    deps = task_dependencies(tasks, p, policy, variant)
    assert len(deps) == len(tasks)
    for idx, row in enumerate(deps):
        assert all(d < idx for d in row), "deps must point backward"
        if tasks[idx].kind == "CONVERT":
            assert len(row) == 1
        else:
            assert len(row) == len(tasks[idx].reads)
    succs = successor_map(deps)
    n_edges = sum(len({d for d in row if d >= 0}) for row in deps)
    assert sum(len(s) for s in succs) == n_edges
    for idx, row in enumerate(deps):
        for d in set(row):
            if d >= 0:
                assert idx in succs[d]


@pytest.mark.parametrize("variant", VARIANTS)
def test_generations_partition_and_order(variant):
    policy = POLICIES["mixed"]
    tasks = build_dag(variant, 6, policy)
    deps = task_dependencies(tasks, 6, policy, variant)
    gens = generations(deps)
    seen = sorted(i for g in gens for i in g)
    assert seen == list(range(len(tasks)))
    depth = {}
    for g, members in enumerate(gens):
        for i in members:
            depth[i] = g
    for idx, row in enumerate(deps):
        for d in row:
            if d >= 0:
                assert depth[d] < depth[idx]
    # generation sizes bound the usable parallelism the scheduler exploits
    assert max(len(g) for g in gens) > 1


def test_task_hashable_dict_key():
    t1 = Task("POTRF", 0, (0, 0), reads=((0, 0),))
    t2 = Task("POTRF", 0, (0, 0), reads=((0, 0),))
    t3 = Task("TRSM", 0, (1, 0), reads=((0, 0), (1, 0)))
    assert t1 == t2 and hash(t1) == hash(t2)
    table = {t1: "a", t3: "b"}
    assert table[t2] == "a" and len(table) == 2


# ---------------------------------------------------------------------------
# simulated backend
# ---------------------------------------------------------------------------

def test_sim_deterministic():
    cfg = SchedConfig(priority="critical_path", workers=4, backend="sim")
    r1 = simulate_dag("tile", 8, POLICIES["mixed"], cfg)
    r2 = simulate_dag("tile", 8, POLICIES["mixed"], cfg)
    assert r1.makespan == r2.makespan
    assert r1.dispatch_order == r2.dispatch_order
    assert [(-e.start, e.end, e.worker) for e in r1.events] \
        == [(-e.start, e.end, e.worker) for e in r2.events]


@pytest.mark.parametrize("priority", ("fifo", "panel_first", "critical_path"))
@pytest.mark.parametrize("workers", (1, 3, 4))
def test_sim_makespan_bounds(priority, workers):
    policy = POLICIES["mixed"]
    graph = build_graph("tile", 8, policy)
    cfg = SchedConfig(priority=priority, workers=workers, backend="sim")
    rep = simulate(graph, cfg)
    serial = sum(task_virtual_cost(t, convert_cost=cfg.convert_cost)
                 for t in graph.tasks)
    cp = max(downstream_cost(graph, cfg))
    assert rep.makespan >= max(serial / workers, cp) - 1e-9
    assert rep.makespan <= serial + 1e-9
    if workers == 1:
        assert rep.makespan == pytest.approx(serial)
        assert rep.overlap_fraction == 0.0
    assert 0.0 < rep.utilization <= 1.0 + 1e-12


@pytest.mark.parametrize("label", sorted(POLICIES))
def test_sim_speedup_at_p8_w4(label):
    """Acceptance: >= 1.5x makespan reduction with 4 workers at p >= 8."""
    graph = build_graph("tile", 8, POLICIES[label])
    r1 = simulate(graph, SchedConfig(priority="critical_path", workers=1,
                                     backend="sim"))
    r4 = simulate(graph, SchedConfig(priority="critical_path", workers=4,
                                     backend="sim"))
    assert r1.makespan / r4.makespan >= 1.5
    assert r4.overlap_fraction > 0.5


def _chain_graph(p: int) -> TaskGraph:
    """A pure dependency chain shaped like the engines' critical path:
    POTRF -> TRSM -> SYRK per step (the 3p-2-task chain of DagReport)."""
    tasks, deps = [], []
    for k in range(p):
        tasks.append(Task("POTRF", k, (k, k), reads=((k, k),)))
        deps.append((len(tasks) - 2,))
        if k < p - 1:
            tasks.append(Task("TRSM", k, (k + 1, k),
                              reads=((k, k), (k + 1, k))))
            deps.append((len(tasks) - 2,))
            tasks.append(Task("SYRK", k, (k + 1, k + 1),
                              reads=((k + 1, k), (k + 1, k + 1))))
            deps.append((len(tasks) - 2,))
    succs = successor_map(deps)
    return TaskGraph(variant="tile", p=p, policy=POLICIES["full"],
                     tasks=tuple(tasks), deps=tuple(tuple(d) for d in deps),
                     succs=tuple(tuple(s) for s in succs))


@pytest.mark.parametrize("workers", (1, 2, 4))
def test_critical_path_not_worse_than_fifo_on_chain(workers):
    graph = _chain_graph(8)
    assert graph.n == 3 * 8 - 2
    mk = {}
    for priority in ("fifo", "critical_path"):
        rep = simulate(graph, SchedConfig(priority=priority, workers=workers,
                                          backend="sim"))
        mk[priority] = rep.makespan
    # on a chain there is nothing to reorder: every policy pays exactly the
    # chain; critical-path must therefore never be longer than FIFO
    assert mk["critical_path"] <= mk["fifo"]
    assert mk["critical_path"] == pytest.approx(mk["fifo"])


@pytest.mark.parametrize("p", (4, 8))
@pytest.mark.parametrize("workers", (2, 4))
def test_graham_bound_every_priority(p, workers):
    """Any greedy list schedule obeys Graham's bound
    makespan <= serial/W + (1 - 1/W) * critical_path; priority lists are
    heuristics (scheduling anomalies mean no total order between them on
    general DAGs -- only the chain guarantee above), but none may ever
    breach the bound."""
    for label, policy in POLICIES.items():
        graph = build_graph("tile", p, policy)
        for priority in ("fifo", "panel_first", "critical_path"):
            cfg = SchedConfig(priority=priority, workers=workers,
                              backend="sim")
            rep = simulate(graph, cfg)
            serial = sum(task_virtual_cost(t, convert_cost=cfg.convert_cost)
                         for t in graph.tasks)
            cp = max(downstream_cost(graph, cfg))
            bound = serial / workers + (1.0 - 1.0 / workers) * cp
            assert rep.makespan <= bound + 1e-9, (label, p, workers, priority)


# ---------------------------------------------------------------------------
# dispatch-order replay through the hazard checker
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("priority", ("fifo", "panel_first", "critical_path"))
@pytest.mark.parametrize("variant", VARIANTS)
def test_dispatch_order_replays_hazard_free(variant, priority):
    for label, policy in POLICIES.items():
        graph = build_graph(variant, 8, policy)
        rep = simulate(graph, SchedConfig(priority=priority, workers=4,
                                          backend="sim"))
        assert sorted(rep.dispatch_order) == list(range(graph.n))
        reordered = [graph.tasks[i] for i in rep.dispatch_order]
        check_dag(reordered, 8, policy, variant,
                  label=f"{label}/sched:{priority}")


def test_cli_sched_replay_gate():
    from repro.analysis.cli import run_sched_replay
    assert run_sched_replay() == 0


# ---------------------------------------------------------------------------
# trace emission + validation
# ---------------------------------------------------------------------------

def test_trace_well_formed_and_validated(tmp_path):
    rep = simulate_dag("tile", 8, POLICIES["mixed"],
                       SchedConfig(priority="critical_path", workers=4,
                                   backend="sim"))
    trace = chrome_trace(rep)
    validate_trace(trace)
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == rep.n_tasks
    assert {e["tid"] for e in xs} <= set(range(4))
    assert {e["cat"] for e in xs} <= {"hi", "lo", "lo2"}
    path = tmp_path / "trace.json"
    write_trace(rep, path)
    loaded = load_and_validate(path)
    assert loaded["otherData"]["n_tasks"] == rep.n_tasks
    json.dumps(loaded)   # round-trippable


def test_trace_path_config_writes(tmp_path):
    path = tmp_path / "auto.json"
    simulate_dag("tile", 4, POLICIES["mixed"],
                 SchedConfig(backend="sim", workers=2, trace_path=str(path)))
    load_and_validate(path)


@pytest.mark.parametrize("corrupt", ["overlap", "missing_key", "negative",
                                     "no_events", "not_a_trace"])
def test_trace_validator_rejects(corrupt):
    rep = simulate_dag("tile", 4, POLICIES["mixed"],
                       SchedConfig(backend="sim", workers=2))
    trace = chrome_trace(rep)
    if corrupt == "overlap":
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"
              and e["tid"] == 0]
        xs[1]["ts"] = xs[0]["ts"]          # two tasks on one worker track
    elif corrupt == "missing_key":
        next(e for e in trace["traceEvents"] if e["ph"] == "X").pop("dur")
    elif corrupt == "negative":
        next(e for e in trace["traceEvents"] if e["ph"] == "X")["ts"] = -1.0
    elif corrupt == "no_events":
        trace["traceEvents"] = [e for e in trace["traceEvents"]
                                if e["ph"] != "X"]
    else:
        trace = {"events": []}
    with pytest.raises(ValueError):
        validate_trace(trace)


def test_cli_main_smoke(tmp_path, capsys):
    from repro.sched.__main__ import main
    path = tmp_path / "cli.json"
    rc = main(["--variant", "tile", "--policy", "mixed", "--p", "6",
               "--workers", "4", "--priority", "critical_path",
               "--backend", "sim", "--trace", str(path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "makespan" in out and path.exists()


# ---------------------------------------------------------------------------
# worker names (PR 10): events, trace metadata, named-tid validation
# ---------------------------------------------------------------------------

def test_sim_events_carry_worker_names():
    rep = simulate_dag("tile", 6, POLICIES["mixed"],
                       SchedConfig(backend="sim", workers=3))
    assert all(ev.worker_name == f"sim-w{ev.worker}" for ev in rep.events)


def test_trace_names_workers_in_metadata_and_args():
    rep = simulate_dag("tile", 6, POLICIES["mixed"],
                       SchedConfig(backend="sim", workers=3))
    trace = chrome_trace(rep)
    meta = {e["tid"]: e["args"]["name"] for e in trace["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert meta == {w: f"sim-w{w}" for w in range(3)}
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert all(e["args"]["worker"] == f"sim-w{e['tid']}" for e in xs)


def test_trace_carries_graph_identity():
    """otherData names (p, policy): the HB verifier rebuilds the DAG from
    the artifact alone."""
    rep = simulate_dag("tile", 6, POLICIES["three_tier"],
                       SchedConfig(backend="sim", workers=2))
    other = chrome_trace(rep)["otherData"]
    assert other["p"] == 6
    assert other["policy"] == {"mode": "three_tier", "diag_thick": 1,
                               "diag_thick2": 3}


def test_validate_trace_accepts_named_tids():
    rep = simulate_dag("tile", 4, POLICIES["mixed"],
                       SchedConfig(backend="sim", workers=2))
    trace = chrome_trace(rep)
    for ev in trace["traceEvents"]:
        if ev["ph"] == "X":
            ev["tid"] = f"sched-w{ev['tid']}"
    validate_trace(trace)


def test_validate_trace_rejects_non_int_non_str_tids():
    rep = simulate_dag("tile", 4, POLICIES["mixed"],
                       SchedConfig(backend="sim", workers=2))
    for bad in (1.5, None, True):
        trace = chrome_trace(rep)
        next(e for e in trace["traceEvents"] if e["ph"] == "X")["tid"] = bad
        with pytest.raises(ValueError, match="tid"):
            validate_trace(trace)
