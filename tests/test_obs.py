"""Telemetry layer tests (repro.obs): recorder semantics, thread safety,
exporter round-trips, the merged Chrome trace, kernel-time calibration, and
the disabled-mode overhead guard.
"""

import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.precision import PrecisionPolicy
from repro.core.tile_cholesky import tile_cholesky
from repro.launch.costmodel import (
    load_calibration,
    set_calibration,
    task_virtual_cost,
)
from repro.obs.calibrate import cost_key, measure_kernel_times, write_calibration
from repro.sched.config import SchedConfig
from repro.sched.runtime import build_graph, scheduled_tile_cholesky, simulate
from repro.sched.trace import validate_trace
from repro.verify.generators import spd_matrix

POLICY = PrecisionPolicy.tpu(2)


# ---------------------------------------------------------------------------
# recorder: counters / gauges / histograms
# ---------------------------------------------------------------------------

def test_counters_and_gauges():
    rec = obs.Recorder()
    rec.inc("a")
    rec.inc("a", 2)
    rec.gauge("g", 3.5)
    rec.gauge("g", 4.5)          # gauges overwrite
    snap = rec.snapshot()
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == 4.5


def test_histogram_bucket_edges_le_semantics():
    h = obs.Histogram(edges=(1.0, 2.0, 4.0))
    # Prometheus `le`: a value equal to an edge lands IN that bucket
    for v in (0.5, 1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    assert h.counts == [2, 1, 2, 1]      # (<=1, <=2, <=4, +Inf overflow)
    assert h.count == 6
    assert h.min == 0.5 and h.max == 5.0
    assert h.total == pytest.approx(15.5)
    # bucket_rows are cumulative; the +Inf row equals the total count
    assert h.bucket_rows() == [(1.0, 2), (2.0, 3), (4.0, 5),
                               (float("inf"), 6)]


def test_histogram_rejects_unsorted_edges():
    with pytest.raises(ValueError):
        obs.Histogram(edges=(2.0, 1.0))


def test_observe_uses_default_buckets():
    rec = obs.Recorder()
    rec.observe("h", 0.5)
    h = rec.histograms["h"]
    assert tuple(h.edges) == obs.recorder.DEFAULT_BUCKETS


# ---------------------------------------------------------------------------
# spans: nesting, exception unwinding
# ---------------------------------------------------------------------------

def test_span_nesting_depths():
    rec = obs.Recorder()
    with rec.span("outer"):
        with rec.span("inner"):
            pass
        with rec.span("inner2"):
            pass
    by_name = {s.name: s for s in rec.spans}
    assert by_name["outer"].depth == 0
    assert by_name["inner"].depth == 1
    assert by_name["inner2"].depth == 1
    # children recorded before the parent closes
    assert [s.name for s in rec.spans] == ["inner", "inner2", "outer"]
    # span durations also feed a histogram of the same name
    assert rec.histograms["outer"].count == 1


def test_span_exception_unwinds_and_propagates():
    rec = obs.Recorder()
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            raise RuntimeError("x")
    (s,) = rec.spans
    assert s.status == "error"
    # depth stack unwound: a fresh span is a root again
    with rec.span("after"):
        pass
    assert rec.spans[-1].depth == 0


def test_span_attrs_recorded():
    rec = obs.Recorder()
    with rec.span("s", n=128, mode="mixed"):
        pass
    assert rec.spans[0].attrs == {"n": 128, "mode": "mixed"}


# ---------------------------------------------------------------------------
# global switch / maybe_span
# ---------------------------------------------------------------------------

def test_disabled_module_helpers_are_noops():
    assert not obs.enabled()
    assert obs.span("x") is obs.NULL_SPAN
    assert obs.maybe_span("x", jnp.zeros(1)) is obs.NULL_SPAN
    before = obs.get_recorder().snapshot()
    obs.inc("c")
    obs.observe("h", 1.0)
    obs.gauge("g", 1.0)
    assert obs.get_recorder().snapshot() == before


def test_recording_restores_previous_state():
    assert not obs.enabled()
    with obs.recording() as rec:
        assert obs.enabled()
        assert obs.get_recorder() is rec
        obs.inc("c")
    assert not obs.enabled()
    assert rec.counters["c"] == 1


def test_maybe_span_noops_under_jit():
    a = np.asarray(spd_matrix(3, 64, cond=10.0))
    with obs.recording() as rec:
        tile_cholesky(jnp.asarray(a), 32, POLICY)            # eager: records
        jax.jit(lambda x: tile_cholesky(x, 32, POLICY))(
            jnp.asarray(a)).block_until_ready()              # traced: no-op
    names = [s.name for s in rec.spans]
    assert names.count("core.tile_cholesky") == 1


# ---------------------------------------------------------------------------
# thread safety
# ---------------------------------------------------------------------------

def test_recorder_thread_safety_raw_threads():
    rec = obs.Recorder()
    n_threads, n_iter = 8, 200

    def work():
        for _ in range(n_iter):
            rec.inc("c")
            rec.observe("h", 1e-4)
            with rec.span("w"):
                pass

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = rec.snapshot()
    assert snap["counters"]["c"] == n_threads * n_iter
    assert snap["histograms"]["h"]["count"] == n_threads * n_iter
    assert len(snap["spans"]) == n_threads * n_iter
    # per-thread depth stacks never bled across threads
    assert all(s.depth == 0 for s in snap["spans"])


def test_recorder_under_threaded_executor():
    """The scheduler's worker pool writes task histograms concurrently."""
    a = spd_matrix(5, 128, cond=100.0)
    with obs.recording() as rec:
        l, report = scheduled_tile_cholesky(
            a, 32, POLICY, SchedConfig(backend="real", workers=4))
    snap = rec.snapshot()
    n_observed = sum(h["count"] for name, h in snap["histograms"].items()
                     if name.startswith("sched.task."))
    assert n_observed == report.n_tasks
    assert sum(v for k, v in snap["counters"].items()
               if k.startswith("sched.tasks.")) == report.n_tasks
    assert "sched.t0" in snap["gauges"]
    assert any(s.name == "sched.execute" for s in snap["spans"])
    # and the factorization itself is still right
    np.testing.assert_allclose(np.asarray(l), np.asarray(
        tile_cholesky(a, 32, POLICY)), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _populated_recorder() -> obs.Recorder:
    rec = obs.Recorder()
    with rec.span("alpha", n=1):
        time.sleep(0.001)
        with rec.span("beta"):
            pass
    try:
        with rec.span("beta"):
            raise ValueError("x")
    except ValueError:
        pass
    rec.inc("count.a", 3)
    rec.gauge("g", 2.5)
    rec.observe("lat", 0.02)
    return rec


def test_jsonl_round_trip(tmp_path):
    rec = _populated_recorder()
    path = tmp_path / "metrics.jsonl"
    n = obs.write_jsonl(rec, path)
    evs = obs.load_jsonl(path)
    assert len(evs) == n
    # aggregates rebuilt from the file match those from the live recorder
    assert obs.summary_from_events(evs) == obs.summary_rows(rec)
    by_type = {}
    for ev in evs:
        by_type.setdefault(ev["type"], []).append(ev)
    assert len(by_type["span"]) == 3
    assert {e["name"] for e in by_type["counter"]} == {"count.a"}
    hist_names = {e["name"] for e in by_type["histogram"]}
    assert {"alpha", "beta", "lat"} <= hist_names
    # every line is valid standalone JSON (append-friendly contract)
    for line in path.read_text().splitlines():
        json.loads(line)


def test_summary_rows_aggregate():
    rec = _populated_recorder()
    rows = {r["name"]: r for r in obs.summary_rows(rec)}
    assert rows["beta"]["count"] == 2
    assert rows["beta"]["errors"] == 1
    assert rows["alpha"]["count"] == 1
    assert rows["alpha"]["total"] >= 0.001


def test_summary_table_renders():
    table = obs.summary_table(_populated_recorder())
    assert "alpha" in table and "count.a" in table and "lat" in table
    assert obs.summary_table(obs.Recorder()) == "(recorder is empty)"


def test_prometheus_text():
    rec = obs.Recorder()
    rec.inc("tasks.done", 5)
    rec.gauge("t0", 1.5)
    h = obs.Histogram(edges=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    rec.histograms["lat"] = h
    text = obs.prometheus_text(rec)
    assert "# TYPE repro_tasks_done counter" in text
    assert "repro_tasks_done 5" in text
    assert "repro_t0 1.5" in text
    # cumulative le buckets + +Inf + sum/count
    assert 'repro_lat_bucket{le="0.1"} 1' in text
    assert 'repro_lat_bucket{le="1"} 2' in text
    assert 'repro_lat_bucket{le="+Inf"} 3' in text
    assert "repro_lat_count 3" in text


# ---------------------------------------------------------------------------
# merged Chrome trace
# ---------------------------------------------------------------------------

def test_merged_trace_validates_with_both_streams(tmp_path):
    a = spd_matrix(7, 128, cond=100.0)
    with obs.recording() as rec:
        with obs.span("host.outer"):
            with obs.span("host.inner"):
                scheduled_tile_cholesky(
                    a, 32, POLICY, SchedConfig(backend="real", workers=2))
    # grab the report again without telemetry for the trace
    with obs.recording():
        _, report = scheduled_tile_cholesky(
            a, 32, POLICY, SchedConfig(backend="real", workers=2))
    path = tmp_path / "merged.json"
    trace = obs.write_merged_trace(report, rec, path)
    validate_trace(trace)
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    pids = {e["pid"] for e in xs}
    assert pids == {0, 1}                      # scheduler tasks + host spans
    assert trace["otherData"]["host_spans"] == len(rec.spans)
    # nested host spans land on distinct depth tracks
    host = [e for e in xs if e["pid"] == 1]
    outer = next(e for e in host if e["name"] == "host.outer")
    inner = next(e for e in host if e["name"] == "host.inner")
    assert outer["tid"] != inner["tid"]
    validate_trace(json.loads(path.read_text()))


def test_merged_trace_without_spans_is_plain_sched_trace():
    rep = simulate(build_graph("tile", 4, POLICY),
                   SchedConfig(backend="sim", workers=2))
    trace = obs.merged_chrome_trace(rep, obs.Recorder())
    assert "host_spans" not in trace["otherData"]
    validate_trace(trace)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

# every execution pair the engines emit (lo2 is storage-only; CONVERTs
# carry it -- see obs/calibrate.py)
EXPECTED_KEYS = {"POTRF/hi", "TRSM/hi", "TRSM/lo", "SYRK/hi", "GEMM/hi",
                 "GEMM/lo", "CONVERT"}


def test_measure_kernel_times_covers_every_pair():
    costs, meta = measure_kernel_times(nb=16, p=4, reps=1)
    assert set(costs) == EXPECTED_KEYS
    assert all(v > 0 for v in costs.values())
    assert meta["units"] == "microseconds"
    graph = build_graph("tile", 4, POLICY)
    assert {cost_key(t) for t in graph.tasks} == EXPECTED_KEYS


def test_write_calibration_round_trip(tmp_path):
    costs = {k: float(i + 1) for i, k in enumerate(sorted(EXPECTED_KEYS))}
    path = write_calibration(costs, {"units": "microseconds"},
                             tmp_path / "cal.json")
    loaded = load_calibration(path)
    assert loaded == {k: round(v, 3) for k, v in costs.items()}


class _FakeTask:
    def __init__(self, kind, tier):
        self.kind, self.tier = kind, tier


def test_task_virtual_cost_calibrated_table():
    table = {"GEMM/lo": 123.0, "CONVERT": 7.0}
    assert task_virtual_cost(_FakeTask("GEMM", "lo"), calibrated=True,
                             table=table) == 123.0
    assert task_virtual_cost(_FakeTask("CONVERT", "lo"), calibrated=True,
                             table=table) == 7.0
    # keys the table lacks fall back to the analytic weight
    analytic = task_virtual_cost(_FakeTask("POTRF", "hi"))
    assert task_virtual_cost(_FakeTask("POTRF", "hi"), calibrated=True,
                             table=table) == analytic


def test_task_virtual_cost_requires_some_table(monkeypatch, tmp_path):
    from repro.launch import costmodel
    monkeypatch.setattr(costmodel, "CALIBRATION_PATH",
                        tmp_path / "missing.json")
    set_calibration(None)        # drop any cached table
    try:
        with pytest.raises(FileNotFoundError):
            task_virtual_cost(_FakeTask("GEMM", "lo"), calibrated=True)
    finally:
        set_calibration(None)    # re-read the real file next time


def test_simulator_responds_to_measured_weights():
    """The acceptance gate: sim makespans/ordering follow the measured
    table, not the analytic weights, when `calibrated=True`."""
    graph = build_graph("tile", 8, POLICY)
    cfg = SchedConfig(backend="sim", workers=4, priority="critical_path")
    base = simulate(graph, cfg)
    # invert the analytic world: CONVERTs and lo math dominate
    table = {"POTRF/hi": 1.0, "TRSM/hi": 1.0, "SYRK/hi": 1.0, "GEMM/hi": 1.0,
             "TRSM/lo": 50.0, "GEMM/lo": 80.0, "CONVERT": 200.0}
    set_calibration(table)
    try:
        cal = simulate(graph, SchedConfig(backend="sim", workers=4,
                                          priority="critical_path",
                                          calibrated=True))
    finally:
        set_calibration(None)
    assert cal.makespan != base.makespan
    # per-task durations in the calibrated schedule match the table
    ev = next(e for e in cal.events if e.kind == "CONVERT")
    assert ev.end - ev.start == pytest.approx(200.0)
    order_base = [e.index for e in sorted(base.events, key=lambda e: (e.start, e.index))]
    order_cal = [e.index for e in sorted(cal.events, key=lambda e: (e.start, e.index))]
    assert order_base != order_cal       # priorities reordered dispatch


def test_sched_config_validates_calibrated_flag():
    with pytest.raises(ValueError):
        SchedConfig(backend="sim", calibrated="yes")


# ---------------------------------------------------------------------------
# disabled-mode overhead guard
# ---------------------------------------------------------------------------

def test_disabled_overhead_under_five_percent():
    """Telemetry off must cost < 5% on a p=8 tile factorization.

    Measured conservatively: per-call cost of a disabled maybe_span x a
    generous estimate of call sites per factorization, against the
    measured factorization wall time.
    """
    assert not obs.enabled()
    a = spd_matrix(9, 256, cond=100.0)
    arr = jnp.asarray(a)

    tile_cholesky(arr, 32, POLICY).block_until_ready()       # warm up
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        tile_cholesky(arr, 32, POLICY).block_until_ready()
    chol_s = (time.perf_counter() - t0) / reps

    n_calls = 20_000                 # >> the handful of real guard checks
    t0 = time.perf_counter()
    for _ in range(n_calls):
        with obs.maybe_span("x", arr):
            pass
    per_call = (time.perf_counter() - t0) / n_calls

    # a p=8 factorization crosses O(p^3) ~ 120 tile ops; budget 10x that
    overhead = per_call * 1200
    assert overhead < 0.05 * chol_s, (
        f"disabled-mode telemetry too expensive: {per_call * 1e9:.0f} ns/call"
        f" x 1200 sites = {overhead * 1e3:.3f} ms vs factorization"
        f" {chol_s * 1e3:.1f} ms")


# ---------------------------------------------------------------------------
# high-contention stress (PR 10): the single-lock recorder loses nothing
# ---------------------------------------------------------------------------

def test_recorder_contention_no_lost_updates():
    """N raw threads hammering ONE counter + ONE histogram: every
    increment and observation lands; bucket sums match the total count."""
    rec = obs.Recorder()
    n_threads, per_thread = 8, 500
    barrier = threading.Barrier(n_threads)

    def hammer(t):
        barrier.wait()          # maximize overlap
        for i in range(per_thread):
            rec.inc("hits")
            rec.observe("lat", (t * per_thread + i) % 7 * 1e-4)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    total = n_threads * per_thread
    snap = rec.snapshot()
    assert snap["counters"]["hits"] == total
    h = rec.histograms["lat"]
    assert h.count == total
    assert sum(h.counts) == total                   # bucket partition
    assert h.bucket_rows()[-1] == (float("inf"), total)  # cumulative top
    assert h.min >= 0.0 and h.max <= 6.1e-4


def test_recorder_contention_spans_and_mixed_ops():
    """Concurrent spans + counters + gauges: span list complete, nesting
    depths consistent, histogram auto-created by span finish is exact."""
    rec = obs.Recorder()
    n_threads, per_thread = 6, 120
    barrier = threading.Barrier(n_threads)

    def hammer(t):
        barrier.wait()
        for i in range(per_thread):
            with rec.span("outer", t=t):
                with rec.span("inner"):
                    rec.inc("ops")
            rec.gauge(f"g{t}", float(i))

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    total = n_threads * per_thread
    snap = rec.snapshot()
    assert snap["counters"]["ops"] == total
    assert len(snap["spans"]) == 2 * total
    by_name = {}
    for s in snap["spans"]:
        by_name.setdefault(s.name, []).append(s)
    assert len(by_name["outer"]) == total
    assert len(by_name["inner"]) == total
    assert all(s.depth == 0 for s in by_name["outer"])
    assert all(s.depth == 1 for s in by_name["inner"])
    assert rec.histograms["outer"].count == total
    assert rec.histograms["inner"].count == total
    assert snap["gauges"] == {f"g{t}": float(per_thread - 1)
                              for t in range(n_threads)}
