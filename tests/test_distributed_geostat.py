"""SPMD-reformulated geostat engine vs the banded numerical reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PrecisionPolicy, banded_loglik,
                        build_banded_covariance, panel_cholesky_banded)
from repro.core.distributed import (build_covariance_distributed,
                                    geostat_loglik_distributed,
                                    loglik_distributed,
                                    panel_cholesky_distributed)
from repro.covariance import make_dataset

N, NB, T = 256, 32, 2


@pytest.fixture(scope="module")
def ds():
    return make_dataset(jax.random.PRNGKey(1), N, [1.0, 0.1, 0.5],
                        nu_static=0.5)


@pytest.fixture(scope="module")
def ll_ref(ds):
    pol = PrecisionPolicy.tpu(diag_thick=T)
    band, off = build_banded_covariance(ds.locs, ds.theta0, nb=NB,
                                        policy=pol, nu_static=0.5)
    band, off = panel_cholesky_banded(band, off, pol)
    return float(banded_loglik(band, off, ds.z, T))


@pytest.mark.parametrize("version", ["masked_full", "aligned"])
def test_distributed_matches_banded(ds, ll_ref, version):
    pol = PrecisionPolicy.tpu(diag_thick=T)
    ll = float(geostat_loglik_distributed(ds.locs, ds.z, ds.theta0, nb=NB,
                                          policy=pol, nu_static=0.5,
                                          version=version))
    assert ll == pytest.approx(ll_ref, abs=1.0)


def test_distributed_band_region_is_zero_in_off(ds):
    pol = PrecisionPolicy.tpu(diag_thick=T)
    off, band = build_covariance_distributed(ds.locs, ds.theta0, nb=NB,
                                             policy=pol, nu_static=0.5)
    p = N // NB
    o = np.asarray(off, np.float32)
    for i in range(p):
        for j in range(p):
            blk = o[i * NB:(i + 1) * NB, j * NB:(j + 1) * NB]
            if i - j >= T:
                assert np.abs(blk).max() > 0
            else:
                assert np.abs(blk).max() == 0


def test_distributed_full_policy_matches_dense(ds):
    """full-precision distributed factorization == LAPACK cholesky."""
    from repro.core import build_covariance, reference_cholesky, loglik_from_factor
    pol = PrecisionPolicy.full(jnp.float32)
    ll = float(geostat_loglik_distributed(ds.locs, ds.z, ds.theta0, nb=NB,
                                          policy=pol, nu_static=0.5))
    cov = build_covariance(ds.locs, ds.theta0, nu_static=0.5, jitter=1e-6,
                           dtype=jnp.float32)
    l_ref = reference_cholesky(cov, jnp.float32)
    ll_dense = float(loglik_from_factor(l_ref, ds.z))
    assert ll == pytest.approx(ll_dense, abs=0.5)


def test_distributed_jits(ds):
    pol = PrecisionPolicy.tpu(diag_thick=T)
    f = jax.jit(lambda th: geostat_loglik_distributed(
        ds.locs, ds.z, th, nb=NB, policy=pol, nu_static=0.5))
    v1 = float(f(ds.theta0))
    v2 = float(f(ds.theta0 * 1.1))
    assert np.isfinite(v1) and np.isfinite(v2) and v1 != v2
