"""Faithful Algorithm-1 tile Cholesky: correctness + precision behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, HYPOTHESIS_SKIP_REASON

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    import strategies as sts

from repro.core import (
    PrecisionPolicy,
    dst_assemble,
    dst_cholesky,
    reference_cholesky,
    tile_cholesky,
)
from conftest import spd_matrix


def test_full_policy_equals_lapack(small_cov):
    l_tile = tile_cholesky(small_cov, 32, PrecisionPolicy.full(jnp.float32))
    l_ref = reference_cholesky(small_cov, jnp.float32)
    np.testing.assert_allclose(np.asarray(l_tile), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("t", [1, 2, 4])
def test_mixed_tpu_pair_close_to_reference(small_cov, t):
    l_mp = tile_cholesky(small_cov, 32, PrecisionPolicy.tpu(diag_thick=t))
    l_ref = reference_cholesky(small_cov, jnp.float32)
    scale = float(jnp.max(jnp.abs(l_ref)))
    err = float(jnp.max(jnp.abs(l_mp - l_ref))) / scale
    assert err < 0.05  # bf16 off-band: ~1e-2 relative is expected
    # reconstruction: L L^T ~ A
    rec = l_mp @ l_mp.T
    np.testing.assert_allclose(np.asarray(rec), np.asarray(small_cov),
                               rtol=0.1, atol=0.05)


def test_mixed_error_decreases_with_band(small_cov):
    l_ref = reference_cholesky(small_cov, jnp.float32)
    errs = []
    for t in [1, 3, 8]:  # p = 8 tiles; t = 8 == full band
        l_mp = tile_cholesky(small_cov, 32, PrecisionPolicy.tpu(diag_thick=t))
        errs.append(float(jnp.max(jnp.abs(l_mp - l_ref))))
    assert errs[2] <= errs[1] <= errs[0] * 1.05
    assert errs[2] < 1e-6  # full band == all hi


def test_paper_cpu_pair_f64_f32(small_cov):
    with jax.experimental.enable_x64():
        cov64 = small_cov.astype(jnp.float64)
        pol = PrecisionPolicy.paper_cpu(diag_thick=2)
        l_mp = tile_cholesky(cov64, 32, pol)
        l_ref = reference_cholesky(cov64, jnp.float64)
        err = float(jnp.max(jnp.abs(l_mp - l_ref)))
        assert l_mp.dtype == jnp.float64
        assert err < 1e-4  # fp32 off-band error scale
        assert err > 1e-9  # but not identical -- SP region is genuinely fp32


def test_three_tier_policy(small_cov):
    pol = PrecisionPolicy.three_tier(diag_thick=2, diag_thick2=5)
    l_mp = tile_cholesky(small_cov, 32, pol)
    l_ref = reference_cholesky(small_cov, jnp.float32)
    rec = l_mp @ l_mp.T
    np.testing.assert_allclose(np.asarray(rec), np.asarray(small_cov),
                               rtol=0.2, atol=0.1)
    # more aggressive than two-tier, so error should be >= two-tier error
    l_two = tile_cholesky(small_cov, 32, PrecisionPolicy.tpu(diag_thick=2))
    assert (float(jnp.max(jnp.abs(l_mp - l_ref)))
            >= float(jnp.max(jnp.abs(l_two - l_ref))) * 0.5)


def test_dst_is_block_diagonal(small_cov):
    blocks = dst_cholesky(small_cov, 32, diag_thick=2)
    n = small_cov.shape[0]
    l = dst_assemble(blocks, n)
    # exact on the diagonal super-blocks, zero elsewhere
    a = np.asarray(small_cov)
    for sl, lb in blocks:
        np.testing.assert_allclose(
            np.asarray(lb @ lb.T), a[sl, sl], rtol=1e-4, atol=1e-5)
    mask = np.zeros((n, n), dtype=bool)
    for sl, _ in blocks:
        mask[sl, sl] = True
    assert np.all(np.asarray(l)[~mask] == 0)


def test_dp_fraction_labels():
    pol = PrecisionPolicy.from_dp_percent(p=20, dp_percent=0.10)
    assert 0.05 < pol.dp_fraction(20) < 0.2
    pol90 = PrecisionPolicy.from_dp_percent(p=20, dp_percent=0.90)
    assert pol90.dp_fraction(20) > 0.8


if HAVE_HYPOTHESIS:
    @given(sts.spd_problems(conds=(10.0, 50.0, 100.0)),
           sts.mixed_policies(max_thick=2))
    @settings(max_examples=8, deadline=None)
    def test_property_mixed_cholesky_reconstructs_spd(problem, pol):
        """Property: for random SPD matrices under any non-dst policy,
        L_mp L_mp^T ~ A within lo-precision tolerance and the factor is
        lower-triangular with positive diagonal."""
        a, nb = problem
        l = tile_cholesky(a, nb, pol)
        l_np = np.asarray(l, np.float64)
        assert np.allclose(l_np, np.tril(l_np))
        assert np.all(np.diag(l_np) > 0)
        scale = np.abs(np.asarray(a)).max()
        assert np.abs(l_np @ l_np.T - np.asarray(a, np.float64)).max() < 0.1 * scale
else:
    @pytest.mark.skip(reason=HYPOTHESIS_SKIP_REASON)
    def test_property_mixed_cholesky_reconstructs_spd():
        pass
