"""Logical-axis sharding resolution + divisibility fallbacks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import (DEFAULT_RULES, ax, batch_spec, constrain,
                                   resolve_spec, set_activation_mesh)


@pytest.fixture()
def mesh2x2():
    if jax.device_count() < 1:
        pytest.skip("no devices")
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_basic(mesh2x2):
    spec = resolve_spec(ax("vocab", "embed"), mesh2x2, shape=(1024, 64))
    assert spec == P("model", "data")


def test_resolve_divisibility_fallback(mesh2x2):
    # 1-device axes always divide; simulate a fat mesh via a fake object
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    spec = resolve_spec(ax("kv_heads", "head_dim"), FakeMesh(),
                        shape=(8, 128))  # 8 kv heads % 16 != 0
    assert spec[0] is None

    spec2 = resolve_spec(ax("experts", "embed", "expert_ffn"), FakeMesh(),
                         shape=(8, 6144, 32768))  # grok: expert_ffn takes TP
    assert spec2[0] is None and spec2[1] == "data" and spec2[2] == "model"

    spec3 = resolve_spec(ax("experts", "embed", "expert_ffn"), FakeMesh(),
                         shape=(128, 2048, 768))  # qwen3-moe: EP wins
    assert spec3[0] == "model"


def test_multi_axis_placement():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16))
    spec = resolve_spec(ax("batch", "."), FakeMesh(), shape=(256, 128))
    assert spec[0] == ("pod", "data")
    flat = resolve_spec(ax("act_expert_flat", "."), FakeMesh(),
                        shape=(327680, 6144))
    assert flat[0] == ("model", "data")


def test_constrain_noop_without_mesh():
    set_activation_mesh(None)
    x = jnp.ones((4, 4))
    assert constrain(x, ax("act_batch", ".")) is x


def test_constrain_with_mesh(mesh2x2):
    set_activation_mesh(mesh2x2)
    try:
        x = jnp.ones((4, 4))
        y = jax.jit(lambda a: constrain(a, ax("act_batch", ".")))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    finally:
        set_activation_mesh(None)


def test_batch_spec_seq_sharded(mesh2x2):
    assert batch_spec(mesh2x2) == P(("data",))
    assert batch_spec(mesh2x2, seq_sharded=True) == P(None, ("data",))
