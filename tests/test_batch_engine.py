"""Batched evaluation engine: batched == sequential across precision modes,
chunking, batched kriging PMSE, and the batched MLE drivers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BatchEngine,
    BatchPlan,
    PrecisionPolicy,
    evaluate_batch,
    fit_mle,
    fit_mle_grid,
    krige,
    make_loglik,
    pmse,
    tile_cholesky,
)
from repro.covariance import make_dataset, matern_covariance

NB = 32
N = 128


@pytest.fixture(scope="module")
def ds():
    return make_dataset(jax.random.PRNGKey(5), N, [1.0, 0.1, 0.5],
                        nu_static=0.5)


@pytest.fixture(scope="module")
def thetas():
    return jnp.array([[1.0, 0.10, 0.5],
                      [0.7, 0.15, 0.5],
                      [1.3, 0.05, 0.5],
                      [0.9, 0.20, 0.5],
                      [1.1, 0.12, 0.5]])


# One tolerance per precision mode: modes sharing fp32 math agree to fp32
# reassociation noise; bf16/fp8 off-band tiles tolerate coarser agreement.
MODES = {
    "full": (PrecisionPolicy.full(jnp.float32), 1e-6),
    "mixed_bf16": (PrecisionPolicy.tpu(diag_thick=2), 1e-5),
    "mixed_fp32": (PrecisionPolicy(mode="mixed", hi=jnp.float32,
                                   lo=jnp.float32, diag_thick=2), 1e-6),
    "dst": (PrecisionPolicy.dst(2), 1e-6),
    "three_tier": (PrecisionPolicy.three_tier(1, 2), 1e-4),
}


@pytest.mark.parametrize("mode", list(MODES))
def test_batched_loglik_equals_sequential(ds, thetas, mode):
    pol, rtol = MODES[mode]
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=pol, nb=NB, nu_static=0.5))
    ll_bat = np.asarray(engine.loglik(thetas), dtype=np.float64)
    ll_seq = np.asarray(engine.loglik_sequential(thetas), dtype=np.float64)
    np.testing.assert_allclose(ll_bat, ll_seq, rtol=rtol)


def test_batched_loglik_panel_path_equals_sequential(ds, thetas):
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=PrecisionPolicy.tpu(2), nb=NB,
                                   nu_static=0.5, path="panel"))
    ll_bat = np.asarray(engine.loglik(thetas), dtype=np.float64)
    ll_seq = np.asarray(engine.loglik_sequential(thetas), dtype=np.float64)
    np.testing.assert_allclose(ll_bat, ll_seq, rtol=1e-5)


def test_chunked_equals_unchunked_with_padding(ds, thetas):
    # B=5 with chunk_size=2 forces padding (5 -> 6) and a 3-chunk lax.map
    plan = BatchPlan(policy=PrecisionPolicy.full(jnp.float32), nb=NB,
                     nu_static=0.5)
    plan_c = BatchPlan(policy=PrecisionPolicy.full(jnp.float32), nb=NB,
                       nu_static=0.5, chunk_size=2)
    ll = BatchEngine(ds.locs, ds.z, plan).loglik(thetas)
    ll_c = BatchEngine(ds.locs, ds.z, plan_c).loglik(thetas)
    assert ll_c.shape == (thetas.shape[0],)
    np.testing.assert_allclose(np.asarray(ll_c), np.asarray(ll), rtol=1e-6)


def test_tile_cholesky_native_leading_batch(ds, thetas):
    pol = PrecisionPolicy.tpu(2)
    covs = matern_covariance(ds.locs, ds.locs, thetas, nu_static=0.5) \
        + 1e-5 * jnp.eye(N)
    l_bat = tile_cholesky(covs.astype(pol.hi), NB, pol)
    assert l_bat.shape == (thetas.shape[0], N, N)
    for b in range(thetas.shape[0]):
        l_one = tile_cholesky(covs[b].astype(pol.hi), NB, pol)
        np.testing.assert_allclose(np.asarray(l_bat[b]), np.asarray(l_one),
                                   atol=1e-5)


@pytest.mark.parametrize("mode", ["full", "mixed_bf16", "dst"])
def test_batched_kriging_pmse_matches_per_candidate(ds, thetas, mode):
    pol, _ = MODES[mode]
    obs, new = slice(0, 96), slice(96, None)
    engine = BatchEngine(ds.locs[obs], ds.z[obs],
                         BatchPlan(policy=pol, nb=NB, nu_static=0.5),
                         locs_new=ds.locs[new], y_true=ds.z[new])
    scores = np.asarray(engine.krige_pmse(thetas))
    # per-candidate reference straight through core/kriging.py
    pred_pol = pol if pol.mode != "dst" else PrecisionPolicy.full(pol.hi)
    for b in range(thetas.shape[0]):
        mu = krige(ds.locs[obs], ds.z[obs], ds.locs[new], thetas[b],
                   pred_pol, nb=NB, nu_static=0.5)
        ref = float(pmse(mu, ds.z[new]))
        assert scores[b] == pytest.approx(ref, rel=1e-4)


def test_evaluate_batch_result(ds, thetas):
    obs, new = slice(0, 96), slice(96, None)
    res = evaluate_batch(ds.locs[obs], ds.z[obs], thetas,
                         BatchPlan(policy=PrecisionPolicy.full(jnp.float32),
                                   nb=NB, nu_static=0.5),
                         locs_new=ds.locs[new], y_true=ds.z[new])
    assert res.logliks.shape == (thetas.shape[0],)
    assert res.pmse is not None and res.pmse.shape == (thetas.shape[0],)
    assert res.best_index == int(np.argmax(res.logliks))
    np.testing.assert_array_equal(res.best_theta,
                                  res.thetas[res.best_index])


@pytest.mark.parametrize("nugget", [0.0, 0.05])
def test_fused_evaluate_matches_separate_programs(ds, thetas, nugget):
    # mixed policy -> plan qualifies for the fused (shared-factor) program;
    # nugget != 0 checks both PMSE paths share the observation model
    obs, new = slice(0, 96), slice(96, None)
    engine = BatchEngine(ds.locs[obs], ds.z[obs],
                         BatchPlan(policy=PrecisionPolicy.tpu(2), nb=NB,
                                   nu_static=0.5, nugget=nugget),
                         locs_new=ds.locs[new], y_true=ds.z[new])
    assert engine._eval_batch is not None
    res = engine.evaluate(thetas)
    np.testing.assert_allclose(res.logliks, np.asarray(engine.loglik(thetas)),
                               rtol=1e-5)
    np.testing.assert_allclose(res.pmse, np.asarray(engine.krige_pmse(thetas)),
                               rtol=1e-4)


def test_grid_search_stays_inside_bounds():
    # optimum of this surrogate (theta = (10, 1)) lies OUTSIDE the bounds;
    # refinement must clamp so the returned theta respects the box
    def f(ths):
        x = jnp.log(ths)
        return -(x[:, 0] - jnp.log(10.0)) ** 2 - (x[:, 1] - 0.0) ** 2

    res = fit_mle_grid(f, [(0.2, 5.0), (0.02, 0.6)], num=5, refine=4)
    assert 0.2 <= res.theta[0] <= 5.0
    assert 0.02 <= res.theta[1] <= 0.6
    # and it pushes to the boundary nearest the optimum
    assert res.theta[0] == pytest.approx(5.0, rel=0.05)
    assert res.theta[1] == pytest.approx(0.6, rel=0.05)


def test_grid_search_all_nonfinite_raises():
    bad = lambda ths: jnp.full(ths.shape[0], jnp.nan)
    with pytest.raises(ValueError, match="non-finite"):
        fit_mle_grid(bad, [(0.2, 5.0), (0.02, 0.6)], num=3, refine=2)


def test_grid_search_finds_neighborhood_of_optimum(ds):
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=PrecisionPolicy.full(jnp.float32),
                                   nb=NB, nu_static=0.5))

    # engine.loglik accepts (B, 2) directly when the plan pins nu
    res = fit_mle_grid(engine.loglik, [(0.2, 5.0), (0.02, 0.6)],
                       num=8, refine=3)
    assert res.n_evals == 3 * 8 * 8
    # grid optimum must (weakly) beat every first-level grid point it saw
    ll_best = engine.loglik(jnp.asarray(res.theta, jnp.float32)[None, :2])
    assert float(ll_best[0]) == pytest.approx(res.loglik, rel=1e-5)
    # and land near the NM optimum of the same likelihood
    ll = make_loglik(ds.locs, ds.z, PrecisionPolicy.full(jnp.float32),
                     nb=NB, nu_static=0.5)
    nm = fit_mle(lambda th: ll(jnp.concatenate([th, jnp.array([0.5])])),
                 [0.8, 0.08], max_iters=60)
    assert res.loglik == pytest.approx(nm.loglik, abs=0.5)


def test_speculative_batched_nm_matches_sequential_nm(ds):
    pol = PrecisionPolicy.full(jnp.float32)
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=pol, nb=NB, nu_static=0.5))
    ll = make_loglik(ds.locs, ds.z, pol, nb=NB, nu_static=0.5)

    def f(th):
        return ll(jnp.concatenate([th, jnp.array([0.5])]))

    r_seq = fit_mle(f, [0.8, 0.08], max_iters=60)
    r_bat = fit_mle(f, [0.8, 0.08], max_iters=60,
                    batched_loglik_fn=engine.loglik)
    assert r_bat.loglik == pytest.approx(r_seq.loglik, abs=1e-3)
    np.testing.assert_allclose(r_bat.theta, r_seq.theta, rtol=0.05)


def test_best_index_raises_when_all_nonfinite(thetas):
    from repro.core import BatchResult
    res = BatchResult(thetas=np.asarray(thetas),
                      logliks=np.full(thetas.shape[0], np.nan))
    with pytest.raises(ValueError, match="non-finite"):
        _ = res.best_theta


def test_best_index_skips_nonfinite_deterministically(thetas):
    # NaN candidates (non-SPD covariances) never win, -inf never wins, and
    # ties resolve to the FIRST maximal finite index -- stable across runs
    from repro.core import BatchResult
    res = BatchResult(thetas=np.asarray(thetas),
                      logliks=np.array([np.nan, -3.0, 2.5, -np.inf, 2.5]))
    assert res.best_index == 2
    assert res.best_loglik == 2.5
    np.testing.assert_array_equal(res.best_theta, np.asarray(thetas)[2])
    # a NaN in front must not shift the argmax (np.argmax on raw NaN would)
    res2 = BatchResult(thetas=np.asarray(thetas),
                       logliks=np.array([np.nan, 7.0, 2.5, 1.0, 2.5]))
    assert res2.best_index == 1


@pytest.mark.parametrize("b", [1, 3, 5, 7])
@pytest.mark.parametrize("chunk_size", [2, 4])
def test_chunked_helper_bitwise_identical(b, chunk_size):
    # padding (repeat-last) + lax.map + unpad must be a pure batching detail:
    # bit-identical to the unchunked fn on every non-divisible batch size
    from repro.core.batch_engine import chunked

    def fn(x):  # batched, non-elementwise: mixes the trailing axes
        return jnp.einsum("bij,bkj->bik", x, x) + jnp.sin(x)

    x = jax.random.normal(jax.random.PRNGKey(b), (b, 8, 8), jnp.float32)
    out = np.asarray(fn(x))
    out_c = np.asarray(chunked(fn, chunk_size)(x))
    assert out_c.shape == out.shape
    np.testing.assert_array_equal(out_c, out)


def test_chunked_engine_loglik_bitwise_across_batch_sizes(ds, thetas):
    # engine-level: the chunked program evaluates the same candidates to the
    # same bits for every B that does not divide the chunk
    plan = BatchPlan(policy=PrecisionPolicy.full(jnp.float32), nb=NB,
                     nu_static=0.5)
    plan_c = BatchPlan(policy=PrecisionPolicy.full(jnp.float32), nb=NB,
                       nu_static=0.5, chunk_size=2)
    for b in (1, 3, 5):
        ll = np.asarray(BatchEngine(ds.locs, ds.z, plan).loglik(thetas[:b]))
        ll_c = np.asarray(BatchEngine(ds.locs, ds.z, plan_c).loglik(thetas[:b]))
        np.testing.assert_array_equal(ll_c, ll)


def test_fit_mle_batched_only_no_scalar_closure(ds):
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=PrecisionPolicy.full(jnp.float32),
                                   nb=NB, nu_static=0.5))
    res = fit_mle(None, [0.8, 0.08], max_iters=60,
                  batched_loglik_fn=engine.loglik)
    assert np.isfinite(res.loglik)
    with pytest.raises(ValueError, match="loglik_fn"):
        fit_mle(None, [0.8, 0.08])


def test_two_column_thetas_equal_pinned_nu_column(ds, thetas):
    engine = BatchEngine(ds.locs, ds.z,
                         BatchPlan(policy=PrecisionPolicy.full(jnp.float32),
                                   nb=NB, nu_static=0.5))
    np.testing.assert_array_equal(np.asarray(engine.loglik(thetas[:, :2])),
                                  np.asarray(engine.loglik(thetas)))


def test_use_tiles_consistent_between_pmse_paths(ds, thetas):
    # use_tiles=False forces the dense reference factor; both public PMSE
    # paths must honor it (krige_pmse used to pick the path from policy.mode
    # alone and diverge from the fused program)
    obs, new = slice(0, 96), slice(96, None)
    engine = BatchEngine(ds.locs[obs], ds.z[obs],
                         BatchPlan(policy=PrecisionPolicy.tpu(2), nb=NB,
                                   nu_static=0.5, use_tiles=False),
                         locs_new=ds.locs[new], y_true=ds.z[new])
    res = engine.evaluate(thetas)
    np.testing.assert_allclose(res.pmse, np.asarray(engine.krige_pmse(thetas)),
                               rtol=1e-6)


def test_profiled_plan_with_prediction_rejected(ds):
    with pytest.raises(ValueError, match="profiled"):
        BatchEngine(ds.locs[:96], ds.z[:96],
                    BatchPlan(policy=PrecisionPolicy.full(jnp.float32),
                              nb=NB, nu_static=0.5, profiled=True),
                    locs_new=ds.locs[96:], y_true=ds.z[96:])


def test_bad_plans_rejected():
    with pytest.raises(ValueError):
        BatchPlan(policy=PrecisionPolicy.full(jnp.float32), path="warp")
    with pytest.raises(ValueError):
        BatchPlan(policy=PrecisionPolicy.dst(2), path="panel")
    with pytest.raises(ValueError):
        BatchPlan(policy=PrecisionPolicy.full(jnp.float32), chunk_size=0)
    # the panel likelihood has no nugget/profiled/use_tiles plumbing --
    # silently evaluating a different model than requested is rejected
    with pytest.raises(ValueError):
        BatchPlan(policy=PrecisionPolicy.tpu(2), path="panel", nugget=0.05)
    with pytest.raises(ValueError):
        BatchPlan(policy=PrecisionPolicy.tpu(2), path="panel", profiled=True)
