"""Runtime substrate: checkpointing, fault tolerance, data pipeline,
gradient compression, optimizer."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data import DataConfig, SyntheticTokenSource
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.runtime import (FaultTolerantLoop, LoopConfig,
                           compress_with_feedback, init_residual,
                           make_failure_injector)
from repro.train import TrainConfig, init_train_state, make_train_step

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, remat=False)


# ----------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_bf16():
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.ones((4,), jnp.bfloat16),
                   "d": jnp.int32(7)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, state, async_=False).result()
        assert ckpt.latest_step(d) == 3
        restored = ckpt.restore(d, 3, state)
        assert restored["b"]["c"].dtype == jnp.bfloat16
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_restore_with_resharding():
    """Restore device_puts each leaf with a target sharding (the elastic
    restore path; on 1 device this exercises the API contract)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.ones((8, 4), jnp.float32)}
    sh = {"w": NamedSharding(mesh, P("data", None))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state, async_=False).result()
        restored = ckpt.restore(d, 1, state, shardings=sh)
        assert restored["w"].sharding == sh["w"]


def test_checkpoint_async_and_gc():
    state = {"x": jnp.zeros((16,))}
    with tempfile.TemporaryDirectory() as d:
        futs = [ckpt.save(d, s, state) for s in (1, 2, 3)]
        for f in futs:
            f.result()
        assert ckpt.latest_step(d) == 3


# ------------------------------------------------------ fault tolerance

def test_fault_tolerant_loop_survives_failures_and_resumes():
    tc = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=30)
    state, _ = init_train_state(jax.random.PRNGKey(0), TINY, tc)
    step = jax.jit(make_train_step(TINY, tc))
    src = SyntheticTokenSource(TINY, DataConfig(seed=0, global_batch=4,
                                                seq_len=16))
    with tempfile.TemporaryDirectory() as d:
        lc = LoopConfig(ckpt_dir=d, ckpt_every=5, max_steps=20)
        loop = FaultTolerantLoop(lc, step, src, state,
                                 failure_injector=make_failure_injector([7, 13]))
        final = loop.run()
        assert loop.restarts == 2
        assert int(final["data_step"]) == 20
        # training stayed healthy across both restarts: every logged loss is
        # finite and bounded (20 steps of a tiny model on random tokens is
        # too short for a reliable loss *decrease* -- asserting one was
        # flaky; and which step each restart resumes from depends on when
        # the ASYNC checkpoint write lands, so replay offsets are not
        # asserted either)
        losses = np.array([m["loss"] for m in loop.metrics_log])
        assert np.all(np.isfinite(losses))
        assert float(np.max(losses)) < float(losses[0]) + 1.0


def test_loop_gives_up_after_max_restarts():
    tc = TrainConfig(total_steps=10)
    state, _ = init_train_state(jax.random.PRNGKey(0), TINY, tc)
    step = jax.jit(make_train_step(TINY, tc))
    src = SyntheticTokenSource(TINY, DataConfig(seed=0, global_batch=4,
                                                seq_len=16))
    with tempfile.TemporaryDirectory() as d:
        lc = LoopConfig(ckpt_dir=d, ckpt_every=100, max_steps=10,
                        max_restarts=1)
        # failing on the same pre-checkpoint step forever
        def injector(s):
            if s == 2:
                raise RuntimeError("persistent failure")
        loop = FaultTolerantLoop(lc, step, src, state,
                                 failure_injector=injector)
        with pytest.raises(RuntimeError):
            loop.run()


# -------------------------------------------------------- data pipeline

def test_pipeline_deterministic_and_host_sharded():
    dc = DataConfig(seed=1, global_batch=8, seq_len=32)
    src = SyntheticTokenSource(TINY, dc)
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # two hosts partition the global batch without overlap
    s0 = SyntheticTokenSource(TINY, DataConfig(seed=1, global_batch=8,
                                               seq_len=32, n_processes=2,
                                               process_index=0))
    s1 = SyntheticTokenSource(TINY, DataConfig(seed=1, global_batch=8,
                                               seq_len=32, n_processes=2,
                                               process_index=1))
    assert s0.batch_at(0)["tokens"].shape == (4, 32)
    assert not np.array_equal(s0.batch_at(0)["tokens"],
                              s1.batch_at(0)["tokens"])


def test_pipeline_labels_shift():
    src = SyntheticTokenSource(TINY, DataConfig(global_batch=2, seq_len=16))
    b = src.batch_at(0)
    # label[i] is the next token of tokens[i] in the same stream
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ----------------------------------------------------------- compression

@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_compression_error_feedback_preserves_sum(mode):
    """With error feedback, quantization error does not accumulate: the
    sum of dequantized grads tracks the sum of true grads."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 64)) * 0.01}
    res = init_residual(grads)
    total_true = jnp.zeros((64, 64))
    total_deq = jnp.zeros((64, 64))
    for i in range(20):
        g = {"w": grads["w"] * (1 + 0.1 * i)}
        deq, res = compress_with_feedback(g, res, mode=mode)
        total_true += g["w"]
        total_deq += deq["w"]
    # residual carries the outstanding error; totals match within it
    err = float(jnp.max(jnp.abs(total_true - total_deq - res["w"])))
    assert err < 1e-3


def test_compression_training_convergence_parity():
    tc_plain = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=30)
    tc_comp = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=30,
                          compression="int8")
    src = SyntheticTokenSource(TINY, DataConfig(global_batch=4, seq_len=16))
    losses = {}
    for name, tc in [("plain", tc_plain), ("int8", tc_comp)]:
        state, _ = init_train_state(jax.random.PRNGKey(0), TINY, tc)
        step = jax.jit(make_train_step(TINY, tc))
        for i in range(25):
            state, m = step(state, src.batch_at(i))
        losses[name] = float(m["loss"])
    assert losses["int8"] < losses["plain"] * 1.15  # parity within 15%


# -------------------------------------------------------------- optimizer

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw.update(params, grads, state, lr=0.1,
                                        weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8,))}
    state = adamw.init(params, moment_dtype=jnp.bfloat16)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.full((8,), 0.1)}
    params2, state2, _ = adamw.update(params, grads, state, lr=0.01)
    assert state2["m"]["w"].dtype == jnp.bfloat16
    assert np.all(np.asarray(params2["w"]) < 1.0)


def test_cosine_schedule_shape():
    lr = adamw.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(1))) < float(lr(jnp.int32(10)))
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(jnp.int32(100))) < 2.5e-4
