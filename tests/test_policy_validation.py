"""PrecisionPolicy validation + band-degeneracy edge cases.

The degenerate corners of the policy space used to be unspecified: a band
wider than the tile grid, a three-tier policy whose second threshold erases
the middle tier, a 1-tile matrix.  These tests pin the intended semantics:
wide bands degenerate to the full path BITWISE, nonsense policies raise at
construction, and every factorization variant handles p = 1.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PrecisionPolicy,
    dst_assemble,
    dst_cholesky,
    reference_cholesky,
    tile_cholesky,
)
from repro.core.panel_cholesky import (
    assemble_from_banded,
    build_banded_covariance,
    panel_cholesky_banded,
)
from repro.verify.generators import matern_problem


# ---- construction-time validation -----------------------------------------

def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="mode"):
        PrecisionPolicy(mode="half", hi=jnp.float32, lo=jnp.bfloat16,
                        diag_thick=1)


@pytest.mark.parametrize("t", [0, -1])
def test_nonpositive_diag_thick_rejected(t):
    with pytest.raises(ValueError, match="diag_thick"):
        PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.bfloat16,
                        diag_thick=t)


def test_three_tier_requires_lo2():
    with pytest.raises(ValueError, match="lo2"):
        PrecisionPolicy(mode="three_tier", hi=jnp.float32, lo=jnp.bfloat16,
                        diag_thick=1, diag_thick2=3)


@pytest.mark.parametrize("t, t2", [(2, 2), (3, 1)])
def test_three_tier_thresholds_must_be_ordered(t, t2):
    # diag_thick2 == diag_thick silently erases the lo tier -- reject it
    with pytest.raises(ValueError, match="diag_thick2"):
        PrecisionPolicy.three_tier(diag_thick=t, diag_thick2=t2)


def test_valid_constructors_still_work():
    assert PrecisionPolicy.three_tier(1, 3).mode == "three_tier"
    assert PrecisionPolicy.full().mode == "full"
    assert PrecisionPolicy.dst(2).mode == "dst"


# ---- dtype-field validation (solve_dtype / accum_dtype) -------------------

@pytest.mark.parametrize("field", ["solve_dtype", "accum_dtype"])
@pytest.mark.parametrize("bad", [jnp.int32, jnp.int8, bool, "int16"])
def test_non_floating_exec_dtypes_rejected(field, bad):
    with pytest.raises(ValueError, match=field):
        PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.bfloat16,
                        diag_thick=2, **{field: bad})


@pytest.mark.parametrize("field", ["solve_dtype", "accum_dtype"])
def test_garbage_exec_dtype_rejected(field):
    with pytest.raises(ValueError, match="dtype"):
        PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.bfloat16,
                        diag_thick=2, **{field: object()})


def test_accum_narrower_than_lo_rejected():
    # a bf16 accumulator under fp32 lo storage would round every MXU
    # partial product below the SP error model the paper assumes
    with pytest.raises(ValueError, match="accum_dtype"):
        PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.float32,
                        diag_thick=2, accum_dtype=jnp.bfloat16)


def test_accum_equal_width_to_lo_allowed():
    pol = PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.bfloat16,
                          diag_thick=2, accum_dtype=jnp.float16)
    assert jnp.dtype(pol.accum_dtype) == jnp.dtype(jnp.float16)


def test_string_float_dtypes_accepted():
    pol = PrecisionPolicy(mode="mixed", hi=jnp.float32, lo=jnp.bfloat16,
                          diag_thick=2, solve_dtype="float32",
                          accum_dtype="float32")
    assert jnp.issubdtype(jnp.dtype(pol.solve_dtype), jnp.floating)


# ---- band >= p degenerates to the full path, bitwise ----------------------

@pytest.fixture(scope="module")
def prob():
    return matern_problem(128, "medium")  # p = 4 tiles


def test_wide_band_mixed_equals_full_bitwise(prob):
    # every tile in band -> every op takes the identical hi-precision branch
    l_full = tile_cholesky(prob.cov, prob.nb, PrecisionPolicy.full(jnp.float32))
    l_wide = tile_cholesky(prob.cov, prob.nb,
                           PrecisionPolicy.tpu(diag_thick=prob.p))
    np.testing.assert_array_equal(np.asarray(l_wide), np.asarray(l_full))


def test_wide_band_three_tier_equals_full_bitwise(prob):
    pol = PrecisionPolicy.three_tier(diag_thick=prob.p,
                                     diag_thick2=prob.p + 1)
    l_3t = tile_cholesky(prob.cov, prob.nb, pol)
    l_full = tile_cholesky(prob.cov, prob.nb, PrecisionPolicy.full(jnp.float32))
    np.testing.assert_array_equal(np.asarray(l_3t), np.asarray(l_full))


def test_dst_wide_band_is_dense_cholesky(prob):
    # one super-tile covers the matrix -> DST degenerates to dense Cholesky
    blocks = dst_cholesky(prob.cov, prob.nb, diag_thick=prob.p)
    assert len(blocks) == 1
    l = dst_assemble(blocks, prob.n)
    np.testing.assert_array_equal(
        np.asarray(l), np.asarray(reference_cholesky(prob.cov, jnp.float32)))


# ---- 1-tile matrices (p = 1) ----------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    return matern_problem(32, "medium", nb=32)  # n == nb -> p = 1


@pytest.mark.parametrize("pol", [
    PrecisionPolicy.full(jnp.float32),
    PrecisionPolicy.tpu(diag_thick=1),
    PrecisionPolicy.three_tier(1, 2),
], ids=["full", "mixed", "three_tier"])
def test_single_tile_tile_engine_is_dense(tiny, pol):
    l = tile_cholesky(tiny.cov, tiny.nb, pol)
    np.testing.assert_array_equal(
        np.asarray(l), np.asarray(reference_cholesky(tiny.cov, jnp.float32)))


def test_single_tile_panel_path(tiny):
    pol = PrecisionPolicy.tpu(diag_thick=2)      # t clamps to p = 1
    band, off = build_banded_covariance(tiny.locs, tiny.theta, nb=tiny.nb,
                                        policy=pol, nu_static=0.5,
                                        jitter=1e-6)
    band, off = panel_cholesky_banded(band, off, pol)
    l = assemble_from_banded(band, off, 1)
    ref = reference_cholesky(tiny.cov, jnp.float32)
    np.testing.assert_allclose(np.asarray(l), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_single_tile_dst(tiny):
    blocks = dst_cholesky(tiny.cov, tiny.nb, diag_thick=1)
    assert len(blocks) == 1
    np.testing.assert_array_equal(
        np.asarray(dst_assemble(blocks, tiny.n)),
        np.asarray(reference_cholesky(tiny.cov, jnp.float32)))
